//! Deterministic fault injection for the measurement path.
//!
//! Real-hardware measurement fails: timeouts, transient driver errors,
//! flaky boards, corrupted timer readings. The `FaultInjector` wraps any
//! [`Measurer`] and injects those failure modes from a seeded plan that is
//! a *pure function* of `(fault_seed, config fingerprint, attempt, slot)` —
//! no mutable schedule state — so the exact same fault sequence replays
//! bit-identically at any `--threads` value, any coordinator chunking, and
//! across checkpoint/resume. The retry/backoff/quarantine policy that
//! consumes these faults lives in `coordinator::RetryPolicy`; device-slot
//! health tracking and ejection live in `tuner::session`.

use super::gpu::gflops;
use super::measure::{Measurement, Measurer};
use crate::space::{Config, DesignSpace};
use crate::util::rng::{hash64, hash_unit};
use std::sync::Mutex;

/// Which fault plan drives the injector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultProfile {
    /// No faults: the wrapper is a single-branch pass-through, bit-identical
    /// to the bare inner measurer and allocation-free.
    Off,
    /// The standard chaos plan: transient errors, timeouts, corrupt/outlier
    /// readings, and one persistently flaky (brownout) device slot.
    Standard,
}

impl FaultProfile {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(FaultProfile::Off),
            "standard" => Some(FaultProfile::Standard),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FaultProfile::Off => "off",
            FaultProfile::Standard => "standard",
        }
    }

    pub fn is_off(self) -> bool {
        self == FaultProfile::Off
    }
}

/// Fault-layer knobs (CLI: `--faults`, `--fault-seed`, `--retry-max`,
/// `--retry-backoff-ms`, `--measure-timeout-ms`). All-`Copy` so the session
/// config stays `Clone`-cheap; `retry_max`/`backoff_base_s` parameterize
/// the coordinator's `RetryPolicy`, the rest drive the injector itself.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    pub profile: FaultProfile,
    /// Seed of the fault plan (a different seed = a different bad day).
    pub fault_seed: u64,
    /// Retries per config after the first attempt (0 = fail immediately).
    pub retry_max: u32,
    /// First retry backoff in simulated seconds; doubles per attempt.
    pub backoff_base_s: f64,
    /// Simulated seconds a timed-out measurement burns before giving up.
    pub measure_timeout_s: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            profile: FaultProfile::Off,
            fault_seed: 0,
            retry_max: 2,
            backoff_base_s: 0.05,
            measure_timeout_s: 0.5,
        }
    }
}

/// Typed cause attached to a failed [`Measurement`] (`Measurement::failure`).
/// Unlike [`super::gpu::MeasureError`] (static validity, deterministic per
/// config), these are *operational* failures of the measurement itself; a
/// quarantined config feeds the cost model exactly like an errored one
/// (gflops 0) instead of panicking the tuning loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeasureFailure {
    /// Transient device/driver error; retryable.
    Transient { attempt: u32, slot: u32 },
    /// The measurement ran past the timeout budget; retryable.
    Timeout { attempt: u32, slot: u32 },
    /// The config landed on a browned-out (flaky) device slot; retryable.
    Brownout { attempt: u32, slot: u32 },
    /// Every allowed attempt failed; the config is given up as errored.
    Quarantined { attempts: u32, slot: u32 },
}

impl MeasureFailure {
    /// Device slot the (last) failing attempt ran on.
    pub fn slot(&self) -> u32 {
        match *self {
            MeasureFailure::Transient { slot, .. }
            | MeasureFailure::Timeout { slot, .. }
            | MeasureFailure::Brownout { slot, .. }
            | MeasureFailure::Quarantined { slot, .. } => slot,
        }
    }

    /// Whether the retry policy may try this config again.
    pub fn is_retryable(&self) -> bool {
        !matches!(self, MeasureFailure::Quarantined { .. })
    }
}

/// One fault decision for a `(config, attempt)` pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    None,
    Transient,
    Timeout,
    /// Bad timer reading: the measurement "succeeds" with a silently
    /// outlier runtime (20–80x). Not retried — nothing looked wrong.
    Corrupt,
    Brownout,
}

// Hash-chain salts: each decision draws from an independent lane of the
// SplitMix64 stream so the marginals stay uncorrelated.
const S_FLAKY: u64 = 0x0F1A_57DE_7EC7_0001;
const S_SLOT: u64 = 0x5107_5107_5107_5107;
const S_KIND: u64 = 0xFA01_7FA0_17FA_017F;
const S_BROWN: u64 = 0xB405_B405_B405_B405;
const S_CORRUPT: u64 = 0xC042_4042_C042_4042;

// Standard-profile marginal rates per attempt (cumulative thresholds).
const P_TRANSIENT: f64 = 0.06;
const P_TIMEOUT: f64 = 0.10; // 0.04 marginal
const P_CORRUPT: f64 = 0.13; // 0.03 marginal
/// A config routed to the flaky slot fails with this probability at EVERY
/// attempt — that persistence is what exhausts retries and produces real
/// quarantines (and, upstream, slot ejection).
const P_BROWNOUT: f64 = 0.85;

/// A `Measurer` wrapper injecting deterministic faults (see module docs).
///
/// Holds no fault-schedule state: the only interior mutability is the same
/// `(elapsed_s, count)` accounting pair `SimMeasurer` keeps, covering the
/// fault-charged seconds and faulted configs the inner measurer never sees.
pub struct FaultInjector<'m> {
    inner: &'m dyn Measurer,
    cfg: FaultConfig,
    device_slots: u32,
    state: Mutex<(f64, usize)>, // (fault-charged secs, faulted configs)
}

impl<'m> FaultInjector<'m> {
    pub fn new(inner: &'m dyn Measurer, cfg: FaultConfig, device_slots: u32) -> Self {
        FaultInjector {
            inner,
            cfg,
            device_slots: device_slots.max(1),
            state: Mutex::new((0.0, 0)),
        }
    }

    /// Root of this plan's hash chain.
    fn h0(&self) -> u64 {
        hash64(self.cfg.fault_seed ^ 0xC0FF_EE00_DEAD_BEE5)
    }

    /// The plan's one persistently flaky slot (None with a single slot:
    /// browning out the only slot would quarantine most of the run).
    pub fn flaky_slot(&self) -> Option<u32> {
        if self.device_slots > 1 {
            Some((hash64(self.h0() ^ S_FLAKY) % self.device_slots as u64) as u32)
        } else {
            None
        }
    }

    /// Pure fault decision for `(config fingerprint, attempt)`: the kind
    /// and the device slot the attempt is routed to. Independent of call
    /// order, batching, and thread count by construction.
    pub fn decide(&self, fingerprint: u64, attempt: u32) -> (FaultKind, u32) {
        let ha = hash64(hash64(self.h0() ^ fingerprint) ^ attempt as u64);
        let slot = (hash64(ha ^ S_SLOT) % self.device_slots as u64) as u32;
        if self.flaky_slot() == Some(slot)
            && hash_unit(ha ^ S_BROWN) < P_BROWNOUT
        {
            return (FaultKind::Brownout, slot);
        }
        let u = hash_unit(ha ^ S_KIND);
        let kind = if u < P_TRANSIENT {
            FaultKind::Transient
        } else if u < P_TIMEOUT {
            FaultKind::Timeout
        } else if u < P_CORRUPT {
            FaultKind::Corrupt
        } else {
            FaultKind::None
        };
        (kind, slot)
    }

    /// Outlier factor for a corrupt reading (20–80x, deterministic).
    fn corrupt_factor(&self, fingerprint: u64, attempt: u32) -> f64 {
        let ha = hash64(hash64(self.h0() ^ fingerprint) ^ attempt as u64);
        20.0 + 60.0 * hash_unit(ha ^ S_CORRUPT)
    }
}

impl Measurer for FaultInjector<'_> {
    fn measure_batch_timed(
        &self,
        space: &DesignSpace,
        configs: &[Config],
    ) -> (Vec<Measurement>, f64) {
        self.measure_batch_attempt(space, configs, 1)
    }

    fn measure_batch_attempt(
        &self,
        space: &DesignSpace,
        configs: &[Config],
        attempt: u32,
    ) -> (Vec<Measurement>, f64) {
        if self.cfg.profile.is_off() {
            // faults off: single branch, straight through — bit-identical
            // to (and allocation-free over) the bare inner measurer
            return self.inner.measure_batch_timed(space, configs);
        }
        if configs.is_empty() {
            return (Vec::new(), 0.0);
        }

        // Decide every config up front; only the survivors (incl. corrupt
        // readings, which "succeed") reach the inner measurer, so the
        // inner per-config-linear cost attribution stays exact.
        let decisions: Vec<(FaultKind, u32)> = configs
            .iter()
            .map(|c| self.decide(space.flat_index(c), attempt))
            .collect();
        let pass: Vec<Config> = configs
            .iter()
            .zip(&decisions)
            .filter(|(_, (k, _))| {
                matches!(k, FaultKind::None | FaultKind::Corrupt)
            })
            .map(|(c, _)| c.clone())
            .collect();
        let (measured, inner_secs) = if pass.is_empty() {
            (Vec::new(), 0.0)
        } else {
            self.inner.measure_batch_timed(space, &pass)
        };

        // Stitch results back into input order; faulted configs become
        // failed measurements carrying their typed cause.
        let mut out = Vec::with_capacity(configs.len());
        let mut cursor = measured.into_iter();
        let mut fault_secs = 0.0f64;
        let mut n_faults = 0u64;
        for (c, &(kind, slot)) in configs.iter().zip(&decisions) {
            match kind {
                FaultKind::None | FaultKind::Corrupt => {
                    // defensive: a short inner result degrades to a
                    // transient fault instead of panicking the loop
                    let mut m = if let Some(m) = cursor.next() {
                        m
                    } else {
                        n_faults += 1;
                        fault_secs += 0.1;
                        out.push(Measurement {
                            config: c.clone(),
                            runtime_ms: None,
                            error: None,
                            gflops: 0.0,
                            failure: Some(MeasureFailure::Transient {
                                attempt,
                                slot,
                            }),
                        });
                        continue;
                    };
                    if kind == FaultKind::Corrupt {
                        if let Some(ms) = m.runtime_ms {
                            // a bad timer reading: silently wrong, never
                            // retried — the caller can't tell it failed
                            let bad =
                                ms * self.corrupt_factor(space.flat_index(c), attempt);
                            m.runtime_ms = Some(bad);
                            m.gflops = gflops(&space.layer, bad);
                            n_faults += 1;
                        }
                    }
                    out.push(m);
                }
                FaultKind::Transient | FaultKind::Brownout => {
                    n_faults += 1;
                    fault_secs += 0.1; // error surfaces fast
                    let failure = if kind == FaultKind::Transient {
                        MeasureFailure::Transient { attempt, slot }
                    } else {
                        MeasureFailure::Brownout { attempt, slot }
                    };
                    out.push(Measurement {
                        config: c.clone(),
                        runtime_ms: None,
                        error: None,
                        gflops: 0.0,
                        failure: Some(failure),
                    });
                }
                FaultKind::Timeout => {
                    n_faults += 1;
                    fault_secs += self.cfg.measure_timeout_s;
                    out.push(Measurement {
                        config: c.clone(),
                        runtime_ms: None,
                        error: None,
                        gflops: 0.0,
                        failure: Some(MeasureFailure::Timeout { attempt, slot }),
                    });
                }
            }
        }
        if n_faults > 0 {
            crate::obs::metrics::add(
                crate::obs::metrics::Counter::FaultsInjected,
                n_faults,
            );
        }
        let faulted = configs.len() - pass.len();
        if faulted > 0 || fault_secs > 0.0 {
            // poison-tolerant like Gate::release: held for the adds only
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            st.0 += fault_secs;
            st.1 += faulted;
        }
        (out, inner_secs + fault_secs)
    }

    fn elapsed_s(&self) -> f64 {
        let extra = self.state.lock().unwrap_or_else(|e| e.into_inner()).0;
        self.inner.elapsed_s() + extra
    }

    fn count(&self) -> usize {
        let faulted = self.state.lock().unwrap_or_else(|e| e.into_inner()).1;
        self.inner.count() + faulted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimMeasurer;
    use crate::util::rng::Pcg32;
    use crate::workload::zoo;

    fn setup() -> (SimMeasurer, DesignSpace, Vec<Config>) {
        let space = DesignSpace::for_conv(zoo::resnet18()[5].layer);
        let mut rng = Pcg32::seed_from(0);
        let configs: Vec<Config> =
            (0..96).map(|_| space.random_config(&mut rng)).collect();
        (SimMeasurer::titan_xp(0), space, configs)
    }

    fn standard(seed: u64) -> FaultConfig {
        FaultConfig {
            profile: FaultProfile::Standard,
            fault_seed: seed,
            ..Default::default()
        }
    }

    #[test]
    fn off_profile_is_bit_identical_to_bare() {
        let (meas, space, configs) = setup();
        let bare = SimMeasurer::titan_xp(0);
        let inj = FaultInjector::new(&meas, FaultConfig::default(), 2);
        let (a, sa) = bare.measure_batch_timed(&space, &configs);
        let (b, sb) = inj.measure_batch_timed(&space, &configs);
        assert_eq!(sa.to_bits(), sb.to_bits());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.runtime_ms, y.runtime_ms);
            assert_eq!(x.gflops.to_bits(), y.gflops.to_bits());
            assert!(y.failure.is_none());
        }
    }

    #[test]
    fn decisions_are_pure_and_batch_invariant() {
        let (meas, space, configs) = setup();
        let inj = FaultInjector::new(&meas, standard(7), 2);
        let whole = inj.measure_batch_timed(&space, &configs).0;
        // a fresh injector measuring one config at a time must reproduce
        // the exact same outcomes: no hidden schedule state
        let meas2 = SimMeasurer::titan_xp(0);
        let inj2 = FaultInjector::new(&meas2, standard(7), 2);
        for (c, w) in configs.iter().zip(&whole) {
            let one = inj2
                .measure_batch_timed(&space, std::slice::from_ref(c))
                .0
                .remove(0);
            assert_eq!(w.runtime_ms, one.runtime_ms);
            assert_eq!(w.failure, one.failure);
        }
    }

    #[test]
    fn standard_profile_injects_every_kind() {
        let (meas, space, configs) = setup();
        let inj = FaultInjector::new(&meas, standard(7), 2);
        let mut kinds = [0usize; 4]; // transient, timeout, brownout, ok
        for m in inj.measure_batch_timed(&space, &configs).0 {
            match m.failure {
                Some(MeasureFailure::Transient { .. }) => kinds[0] += 1,
                Some(MeasureFailure::Timeout { .. }) => kinds[1] += 1,
                Some(MeasureFailure::Brownout { .. }) => kinds[2] += 1,
                _ => kinds[3] += 1,
            }
        }
        assert!(kinds[0] > 0, "no transients: {kinds:?}");
        assert!(kinds[1] > 0, "no timeouts: {kinds:?}");
        assert!(kinds[2] > 0, "no brownouts: {kinds:?}");
        assert!(kinds[3] > configs.len() / 2, "mostly ok: {kinds:?}");
    }

    #[test]
    fn faults_charge_simulated_seconds() {
        let (meas, space, configs) = setup();
        let inj = FaultInjector::new(&meas, standard(7), 2);
        let bare = SimMeasurer::titan_xp(0);
        let (out, secs) = inj.measure_batch_timed(&space, &configs);
        let passed: Vec<Config> = out
            .iter()
            .filter(|m| m.failure.is_none())
            .map(|m| m.config.clone())
            .collect();
        let (_, pass_secs) = bare.measure_batch_timed(&space, &passed);
        // total = inner cost of the survivors + per-fault charges
        assert!(secs > pass_secs);
        assert!((inj.elapsed_s() - secs).abs() < 1e-9);
        assert_eq!(inj.count(), configs.len());
    }

    #[test]
    fn corrupt_readings_are_silent_outliers() {
        let (meas, space, configs) = setup();
        let inj = FaultInjector::new(&meas, standard(7), 2);
        let bare = SimMeasurer::titan_xp(0);
        let clean = bare.measure_batch(&space, &configs);
        let faulted = inj.measure_batch(&space, &configs);
        let mut n_corrupt = 0;
        for (c, f) in clean.iter().zip(&faulted) {
            if f.failure.is_some() || !c.ok() {
                continue;
            }
            let (a, b) = (c.runtime_ms.unwrap(), f.runtime_ms.unwrap());
            if a != b {
                n_corrupt += 1;
                let factor = b / a;
                assert!(
                    (19.9..80.1).contains(&factor),
                    "corrupt factor {factor}"
                );
                assert!(f.gflops < c.gflops);
            }
        }
        assert!(n_corrupt > 0, "seed 7 over 96 configs should corrupt some");
    }

    #[test]
    fn flaky_slot_brownout_persists_across_attempts() {
        let (meas, space, configs) = setup();
        let inj = FaultInjector::new(&meas, standard(7), 2);
        let flaky = inj.flaky_slot().expect("2 slots -> one flaky");
        // any config browned out at attempt 1 AND routed to the flaky slot
        // again at attempt 2 must usually brown out again (p = 0.85)
        let (mut again, mut routed) = (0u32, 0u32);
        for c in &configs {
            let fp = space.flat_index(c);
            if inj.decide(fp, 1).0 == FaultKind::Brownout {
                let (k2, s2) = inj.decide(fp, 2);
                if s2 == flaky {
                    routed += 1;
                    if k2 == FaultKind::Brownout {
                        again += 1;
                    }
                }
            }
        }
        assert!(routed > 0, "no repeat routings to the flaky slot");
        assert!(again * 2 > routed, "brownout not persistent: {again}/{routed}");
    }

    #[test]
    fn single_slot_has_no_flaky_slot() {
        let (meas, _, _) = setup();
        let inj = FaultInjector::new(&meas, standard(7), 1);
        assert_eq!(inj.flaky_slot(), None);
    }

    #[test]
    fn different_seeds_differ() {
        let (meas, space, configs) = setup();
        let a = FaultInjector::new(&meas, standard(1), 2);
        let b = FaultInjector::new(&meas, standard(2), 2);
        let differs = configs.iter().any(|c| {
            let fp = space.flat_index(c);
            a.decide(fp, 1) != b.decide(fp, 1)
        });
        assert!(differs);
    }
}
