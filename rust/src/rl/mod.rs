//! The reinforcement-learning search agent (paper §4.1): PPO driven from
//! rust over a [`crate::runtime::Backend`] (native `nn` networks by
//! default, AOT XLA artifacts via PJRT when selected), GAE host-side.

pub mod agent;
pub mod gae;

pub use agent::{PpoAgent, PpoAgentParams};
pub use gae::gae;
