//! The reinforcement-learning search agent (paper §4.1): PPO driven from
//! rust over AOT XLA artifacts, GAE host-side.

pub mod agent;
pub mod gae;

pub use agent::{PpoAgent, PpoAgentParams};
pub use gae::gae;
