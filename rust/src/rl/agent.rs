//! The RELEASE search agent (paper §4.1): PPO walkers over the design
//! space, driven from rust, with the policy/value networks and the whole
//! clipped-PPO + Adam update executing behind the [`Backend`] trait —
//! the pure-Rust `nn` backend by default, or the AOT-XLA artifacts when
//! PJRT is selected.
//!
//! Per search round:
//!   1. `b_policy` parallel walkers start from random configurations;
//!   2. for each of H steps, one `policy_forward` backend call yields
//!      per-dim {dec, stay, inc} distributions; actions are sampled in rust
//!      and the configuration updater applies them (an all-stay action ends
//!      the episode — "the agent ends the episode after reaching
//!      convergence");
//!   3. rewards are the cost model's predicted fitness (the surrogate
//!      reward of §4.1) queried per step;
//!   4. GAE(γ=0.9, λ=0.99) runs host-side; one `ppo_update` call trains
//!      both networks;
//!   5. episode batches repeat until the best predicted score plateaus.
//!
//! The policy parameters persist across rounds and across tuner iterations,
//! which is exactly the information reuse of Eq. 3 that lets RL converge in
//! fewer steps than simulated annealing (Fig. 5).

use super::gae::gae;
use crate::costmodel::CostModel;
use crate::runtime::{AgentState, Backend};
use crate::search::{dedup_top, SearchRound, Searcher};
use crate::snapshot::{SnapReader, SnapWriter, SnapshotError};
use crate::space::{Config, DesignSpace, Direction};
use crate::util::rng::Pcg32;
use std::collections::BTreeSet;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct PpoAgentParams {
    /// Max episode batches per round.
    pub max_batches: usize,
    /// Minimum batches before convergence can fire.
    pub min_batches: usize,
    /// Stop after this many non-improving batches.
    pub patience: usize,
    pub traj_cap: usize,
    /// Simulated host+device seconds per episode batch (policy forwards +
    /// one PPO update; measured ~50 ms on this machine, charged at the
    /// paper's host scale).
    pub batch_cost_s: f64,
}

impl Default for PpoAgentParams {
    fn default() -> Self {
        PpoAgentParams {
            max_batches: 24,
            min_batches: 4,
            patience: 3,
            traj_cap: 512,
            batch_cost_s: 0.35,
        }
    }
}

pub struct PpoAgent {
    backend: Arc<dyn Backend>,
    pub params: PpoAgentParams,
    state: Option<AgentState>,
    init_seed: i32,
    update_seed: i32,
    /// Best measured configs fed back by the tuner — half of each episode
    /// batch starts from perturbations of these (exploitation).
    seed_configs: Vec<Config>,
}

impl PpoAgent {
    pub fn new(backend: Arc<dyn Backend>, seed: i32) -> Self {
        PpoAgent {
            backend,
            params: PpoAgentParams::default(),
            state: None,
            init_seed: seed,
            update_seed: seed.wrapping_mul(7919),
            seed_configs: Vec::new(),
        }
    }

    fn ensure_state(&mut self) {
        if self.state.is_none() {
            self.state = Some(
                self.backend
                    .ppo_init(self.init_seed)
                    .expect("ppo_init backend execution failed"),
            );
        }
    }

    /// Sample one categorical action per dimension from flattened
    /// log-probs [b, ndims, nact]; returns (directions, summed logp) per row.
    fn sample_actions(
        logp: &[f32],
        b: usize,
        ndims: usize,
        nact: usize,
        rng: &mut Pcg32,
    ) -> (Vec<Vec<Direction>>, Vec<f32>, Vec<Vec<i32>>) {
        let mut dirs = Vec::with_capacity(b);
        let mut logps = Vec::with_capacity(b);
        let mut acts = Vec::with_capacity(b);
        for i in 0..b {
            let mut row_dirs = Vec::with_capacity(ndims);
            let mut row_acts = Vec::with_capacity(ndims);
            let mut lp_sum = 0.0f32;
            for d in 0..ndims {
                let off = (i * ndims + d) * nact;
                let probs: Vec<f64> =
                    (0..nact).map(|a| logp[off + a].exp() as f64).collect();
                let a = rng.categorical(&probs);
                lp_sum += logp[off + a];
                row_dirs.push(Direction::from_index(a));
                row_acts.push(a as i32);
            }
            dirs.push(row_dirs);
            logps.push(lp_sum);
            acts.push(row_acts);
        }
        (dirs, logps, acts)
    }
}

impl Searcher for PpoAgent {
    fn name(&self) -> &'static str {
        "rl"
    }

    fn reset(&mut self) {
        // Fresh policy for a fresh task (per-task agents, like the paper).
        self.state = None;
        self.seed_configs.clear();
    }

    fn seed(&mut self, configs: &[Config]) {
        self.seed_configs = configs.to_vec();
    }

    /// Cross-task policy transfer: continue from a donor's parameters
    /// (validated upstream via `Backend::warm_state`) instead of `ppo_init`.
    /// A topology mismatch is ignored — the agent then initializes fresh.
    fn warm_start(&mut self, state: AgentState) {
        if state.params.len() == self.backend.spec().nparams {
            self.state = Some(state);
        }
    }

    fn export_state(&self) -> Option<AgentState> {
        self.state.clone()
    }

    // Cross-round state: the learned parameters + Adam moments (if the
    // policy has been initialized), the PPO update-seed cursor, and the
    // exploitation seed configs fed back by the tuner. `init_seed` is
    // reconstructed from the tuner config on restore.
    fn snap_save(&self, w: &mut SnapWriter) {
        match &self.state {
            Some(s) => {
                w.put_bool(true);
                w.put_f32_slice(&s.params);
                w.put_f32_slice(&s.m);
                w.put_f32_slice(&s.v);
                w.put_f32(s.t);
            }
            None => w.put_bool(false),
        }
        w.put_i64(self.update_seed as i64);
        w.put_configs(&self.seed_configs);
    }

    fn snap_restore(&mut self, r: &mut SnapReader) -> Result<(), SnapshotError> {
        self.state = if r.get_bool()? {
            let params = r.get_f32_vec()?;
            let m = r.get_f32_vec()?;
            let v = r.get_f32_vec()?;
            let t = r.get_f32()?;
            Some(AgentState { params, m, v, t })
        } else {
            None
        };
        self.update_seed = r.get_i64()? as i32;
        self.seed_configs = r.get_configs()?;
        Ok(())
    }

    fn round(
        &mut self,
        space: &DesignSpace,
        model: &CostModel,
        _visited: &BTreeSet<u64>,
        rng: &mut Pcg32,
    ) -> SearchRound {
        let m = self.backend.spec().clone();
        let b = m.b_policy;
        let ndims = m.ndims;
        let horizon = m.b_rollout / m.b_policy;
        let p = self.params.clone();
        self.ensure_state();

        let mut trajectory: Vec<(Config, f64)> = Vec::new();
        let mut best = f64::NEG_INFINITY;
        let mut last_improve = 0usize;
        let mut batches = 0usize;

        for batch in 0..p.max_batches {
            batches = batch + 1;

            // --- rollout -----------------------------------------------------
            // Walkers: three quarters explore from uniform-random starts, one
            // quarter exploits perturbations of the best measured configs fed
            // back by the tuner (information reuse across iterations, Eq. 3).
            // Heavier exploitation couples badly with clustering-based
            // sampling: a bad early basin becomes self-reinforcing.
            let mut configs: Vec<Config> = (0..b)
                .map(|i| {
                    if !self.seed_configs.is_empty() && i % 4 == 0 {
                        let base = rng.choose(&self.seed_configs).clone();
                        let once = space.mutate(&base, rng);
                        if rng.bool(0.5) {
                            once
                        } else {
                            space.mutate(&once, rng)
                        }
                    } else {
                        space.random_config(rng)
                    }
                })
                .collect();
            let mut alive = vec![true; b];

            // per-step storage
            let mut all_obs: Vec<f32> = Vec::with_capacity(b * horizon * ndims);
            let mut all_actions: Vec<i32> = Vec::with_capacity(b * horizon * ndims);
            let mut all_logp: Vec<f32> = Vec::with_capacity(b * horizon);
            let mut rewards: Vec<Vec<f32>> = vec![Vec::with_capacity(horizon); b];
            let mut values: Vec<Vec<f32>> = vec![Vec::with_capacity(horizon + 1); b];
            let mut masks: Vec<Vec<f32>> = vec![Vec::with_capacity(horizon); b];

            for _step in 0..horizon {
                let obs: Vec<f32> =
                    configs.iter().flat_map(|c| space.normalize(c)).collect();
                let state = self.state.as_ref().unwrap();
                let (logp, value) = self
                    .backend
                    .policy_forward(state, &obs)
                    .expect("policy_forward failed");
                let (dirs, lp, acts) =
                    Self::sample_actions(&logp, b, ndims, m.nact, rng);

                let new_configs: Vec<Config> = (0..b)
                    .map(|i| {
                        if alive[i] {
                            space.apply_actions(&configs[i], &dirs[i])
                        } else {
                            configs[i].clone()
                        }
                    })
                    .collect();
                let mut scores = model.predict_batch(space, &new_configs);
                // static screen (TVM verify_gpu_code analogue): invalid
                // configs get the failed-measurement score so the agent
                // learns to stay in the launchable region from episode one
                crate::sim::screen_scores(space, &new_configs, &mut scores);

                for i in 0..b {
                    all_obs.extend_from_slice(&obs[i * ndims..(i + 1) * ndims]);
                    all_actions.extend_from_slice(&acts[i]);
                    all_logp.push(lp[i]);
                    masks[i].push(if alive[i] { 1.0 } else { 0.0 });
                    values[i].push(value[i]);
                    rewards[i].push(if alive[i] { scores[i] as f32 } else { 0.0 });
                    if alive[i] {
                        trajectory.push((new_configs[i].clone(), scores[i]));
                        if scores[i] > best + 1e-9 {
                            best = scores[i];
                            last_improve = batches;
                        }
                        // "end the episode after reaching convergence":
                        // an all-stay action is the agent's stop signal
                        if dirs[i].iter().all(|d| *d == Direction::Stay) {
                            alive[i] = false;
                        }
                    }
                }
                configs = new_configs;
            }

            // bootstrap values for the final states
            let obs: Vec<f32> =
                configs.iter().flat_map(|c| space.normalize(c)).collect();
            let state = self.state.as_ref().unwrap();
            let (_, vlast) = self
                .backend
                .policy_forward(state, &obs)
                .expect("policy_forward failed");
            for i in 0..b {
                values[i].push(vlast[i]);
            }

            // --- GAE + update -----------------------------------------------
            let mut adv_flat = vec![0.0f32; b * horizon];
            let mut ret_flat = vec![0.0f32; b * horizon];
            let mut mask_flat = vec![0.0f32; b * horizon];
            for i in 0..b {
                let (adv, ret) = gae(
                    &rewards[i],
                    &values[i],
                    &masks[i],
                    m.discount as f32,
                    m.gae_lambda as f32,
                );
                for t in 0..horizon {
                    // rollout batch is time-major per walker: row = t*b + i
                    let row = t * b + i;
                    adv_flat[row] = adv[t];
                    ret_flat[row] = ret[t];
                    mask_flat[row] = masks[i][t];
                }
            }
            // reorder obs/actions/logp the same way (collected walker-major
            // per step, which IS time-major rows of t*b + i already)
            self.update_seed = self.update_seed.wrapping_add(1);
            let state = self.state.as_mut().unwrap();
            self.backend
                .ppo_update(
                    state,
                    &all_obs,
                    &all_actions,
                    &all_logp,
                    &adv_flat,
                    &ret_flat,
                    &mask_flat,
                    self.update_seed,
                )
                .expect("ppo_update failed");
            crate::obs::metrics::inc(crate::obs::metrics::Counter::PpoUpdates);
            // Anchor each update on the task's simulated timeline: the
            // round's search time is `batches * batch_cost_s` from the
            // round start, so batch `b` spans the b-th slice.
            crate::obs::emit_ctx(
                "rl",
                "ppo_update",
                crate::obs::ctx_base() + crate::obs::us(batch as f64 * p.batch_cost_s),
                crate::obs::us(p.batch_cost_s),
                &[("batch", batch as f64), ("walkers", b as f64)],
            );

            if batches >= p.min_batches && batches - last_improve >= p.patience {
                break;
            }
        }

        let horizon_steps = batches * horizon;
        let (configs, scores) = dedup_top(space, trajectory, p.traj_cap);
        SearchRound {
            trajectory: configs,
            scores,
            steps: horizon_steps,
            steps_to_converge: (last_improve.max(1)) * horizon,
            sim_time_s: batches as f64 * p.batch_cost_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::NativeBackend;
    use crate::sim::{Measurer, SimMeasurer};
    use crate::workload::zoo;

    fn backend() -> Arc<dyn Backend> {
        Arc::new(NativeBackend::new())
    }

    #[test]
    fn sample_actions_respects_distribution() {
        let mut rng = Pcg32::seed_from(0);
        // 1 row, 2 dims, 3 actions: dim0 ~ always action 2, dim1 uniform
        let mut logp = vec![f32::NEG_INFINITY, f32::NEG_INFINITY, 0.0];
        logp.extend_from_slice(&[(1.0f32 / 3.0).ln(); 3]);
        let mut counts = [0usize; 3];
        for _ in 0..300 {
            let (dirs, lp, acts) = PpoAgent::sample_actions(&logp, 1, 2, 3, &mut rng);
            assert_eq!(dirs[0][0], Direction::Inc);
            counts[acts[0][1] as usize] += 1;
            assert!(lp[0].is_finite());
        }
        for &c in &counts {
            assert!(c > 50, "{counts:?}");
        }
    }

    #[test]
    fn round_produces_trajectory_and_converges() {
        let space = DesignSpace::for_conv(zoo::resnet18()[5].layer);
        let meas = SimMeasurer::titan_xp(0);
        let mut rng = Pcg32::seed_from(1);
        let mut cm = CostModel::new(1);
        let train: Vec<_> = (0..150).map(|_| space.random_config(&mut rng)).collect();
        cm.update(&space, &meas.measure_batch(&space, &train));

        let mut agent = PpoAgent::new(backend(), 42);
        agent.params.max_batches = 6;
        let r = agent.round(&space, &cm, &BTreeSet::new(), &mut rng);
        assert!(!r.trajectory.is_empty());
        assert_eq!(r.trajectory.len(), r.scores.len());
        assert!(r.steps >= 8 && r.steps <= 6 * 8);
        assert!(r.steps_to_converge <= r.steps);
        // scores sorted best-first and finite
        assert!(r.scores.windows(2).all(|w| w[0] >= w[1]));
        assert!(r.scores.iter().all(|s| s.is_finite()));
    }

    #[test]
    fn policy_improves_on_model_surface_across_rounds() {
        // After a few rounds of PPO against a trained cost model, the best
        // score the agent reaches should not degrade (information reuse).
        let space = DesignSpace::for_conv(zoo::resnet18()[1].layer);
        let meas = SimMeasurer::titan_xp(0);
        let mut rng = Pcg32::seed_from(2);
        let mut cm = CostModel::new(2);
        let train: Vec<_> = (0..250).map(|_| space.random_config(&mut rng)).collect();
        cm.update(&space, &meas.measure_batch(&space, &train));

        let mut agent = PpoAgent::new(backend(), 7);
        agent.params.max_batches = 5;
        agent.params.min_batches = 5; // fixed batches for comparability
        let r1 = agent.round(&space, &cm, &BTreeSet::new(), &mut rng);
        let r2 = agent.round(&space, &cm, &BTreeSet::new(), &mut rng);
        let r3 = agent.round(&space, &cm, &BTreeSet::new(), &mut rng);
        let later = r2.scores[0].max(r3.scores[0]);
        assert!(
            later >= r1.scores[0] - 0.3,
            "r1 {} r2 {} r3 {}",
            r1.scores[0],
            r2.scores[0],
            r3.scores[0]
        );
    }
}
