//! Generalized Advantage Estimation (host side). The rollout is collected
//! by the rust agent driver; GAE runs here on CPU (it's O(T) and trivially
//! cheap), and the resulting tensors feed the XLA `ppo_update` artifact.
//!
//! Table 2: discount γ = 0.9, GAE λ = 0.99.

/// Compute advantages and returns for one episode.
///
/// `rewards[t]` is received after taking the action in state t;
/// `values[t]` is V(s_t) for t in 0..T, plus a bootstrap `values[T]`;
/// `mask[t]` is 1.0 iff transition t is valid (the step was taken while the
/// episode was live). The last valid transition before a masked one is
/// terminal (no bootstrap); an episode still live at the horizon is
/// *truncated* and bootstraps through `values[T]`.
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    mask: &[f32],
    gamma: f32,
    lambda: f32,
) -> (Vec<f32>, Vec<f32>) {
    let t_len = rewards.len();
    assert_eq!(values.len(), t_len + 1, "values needs a bootstrap entry");
    assert_eq!(mask.len(), t_len);
    let mut adv = vec![0.0f32; t_len];
    let mut acc = 0.0f32;
    for t in (0..t_len).rev() {
        // continuation: does state t+1 exist for credit purposes?
        let cont = if t + 1 < t_len { mask[t + 1] } else { 1.0 };
        let delta = rewards[t] + gamma * values[t + 1] * cont - values[t];
        acc = delta + gamma * lambda * cont * acc;
        adv[t] = acc * mask[t];
    }
    let returns: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, returns)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_episode_is_td_error() {
        let (adv, ret) = gae(&[1.0], &[0.25, 0.5], &[1.0], 0.9, 0.99);
        let delta = 1.0 + 0.9 * 0.5 - 0.25;
        assert!((adv[0] - delta).abs() < 1e-6);
        assert!((ret[0] - (delta + 0.25)).abs() < 1e-6);
    }

    #[test]
    fn zero_reward_perfect_value_gives_zero_advantage() {
        // V == discounted future rewards == 0 everywhere
        let (adv, _) = gae(&[0.0; 5], &[0.0; 6], &[1.0; 5], 0.9, 0.99);
        assert!(adv.iter().all(|&a| a.abs() < 1e-7));
    }

    #[test]
    fn constant_reward_advantages_decay_backwards() {
        let (adv, _) = gae(&[1.0; 4], &[0.0; 5], &[1.0; 4], 0.9, 0.99);
        // earlier steps accumulate more future reward => larger advantage
        assert!(adv[0] > adv[1] && adv[1] > adv[2] && adv[2] > adv[3]);
        assert!((adv[3] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mask_truncates_credit_assignment() {
        // episode terminates at step 1: steps 2,3 contribute nothing, and
        // the terminal step gets no bootstrap even with nonzero values[2..]
        let (adv, _) = gae(
            &[1.0, 1.0, 99.0, 99.0],
            &[0.0, 0.0, 5.0, 5.0, 5.0],
            &[1.0, 1.0, 0.0, 0.0],
            0.9,
            0.99,
        );
        assert!((adv[1] - 1.0).abs() < 1e-6); // terminal step: just its reward
        assert_eq!(adv[2], 0.0);
        assert_eq!(adv[3], 0.0);
        // step 0 sees step 1's reward through gamma*lambda
        assert!((adv[0] - (1.0 + 0.9 * 0.99 * 1.0)).abs() < 1e-5);
    }

    #[test]
    fn truncated_episode_bootstraps_final_value() {
        // live at horizon: the last step must see gamma * values[T]
        let (adv, _) = gae(&[0.0, 0.0], &[0.0, 0.0, 2.0], &[1.0, 1.0], 0.9, 0.99);
        assert!((adv[1] - 0.9 * 2.0).abs() < 1e-6);
    }

    #[test]
    fn lambda_zero_is_one_step_td() {
        let rewards = [0.5, 0.25];
        let values = [0.1, 0.2, 0.3];
        let (adv, _) = gae(&rewards, &values, &[1.0, 1.0], 0.9, 0.0);
        assert!((adv[0] - (0.5 + 0.9 * 0.2 - 0.1)).abs() < 1e-6);
        assert!((adv[1] - (0.25 + 0.9 * 0.3 - 0.2)).abs() < 1e-6);
    }
}
