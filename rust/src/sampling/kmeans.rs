//! k-means (k-means++ init + Lloyd iterations) over normalized
//! configuration coordinates — the engine of the adaptive sampling module
//! (paper Algorithm 1). This is a hot path: it runs for every k in the
//! knee sweep, every tuning iteration.
//!
//! §Perf: points and centroids live in flat [`FeatureMatrix`] buffers,
//! distances go through the shared lane-unrolled [`dist2`] kernel
//! (`util::simd`), and the Lloyd *assignment* sweep (the O(n·k·d) part)
//! distributes points over the persistent worker pool on large workloads.
//! Seeding — the only stochastic part — always runs serially, and the
//! per-point loss fold keeps its original order, so any thread count
//! produces bit-identical clusterings.

use crate::util::matrix::FeatureMatrix;
use crate::util::parallel::{gate, par_indexed_mut, threads};
use crate::util::rng::Pcg32;
use crate::util::simd::dist2;

#[derive(Debug, Clone)]
pub struct KMeansResult {
    /// k centroids, one row each.
    pub centroids: FeatureMatrix,
    /// Cluster assignment per input point.
    pub assignment: Vec<u32>,
    /// Total within-cluster sum of squared distances ("Loss" in Alg. 1).
    pub loss: f64,
}

/// Below this n x k x d workload the assignment sweep stays serial
/// (dispatch overhead would dominate; [`gate`] scales it ~16x back up when
/// the scoped spawn-per-call dispatch is active). Thread-count independent,
/// so the parallel/serial choice never changes results.
const PAR_ASSIGN_MIN_WORK: usize = 1 << 12;

/// k-means++ seeding — consumes the RNG exactly as the combined
/// `kmeans` always has (Lloyd draws nothing), which is what lets the
/// adaptive sampler's knee sweep speculate across k while preserving the
/// serial RNG stream.
pub(crate) fn seed_centroids(points: &FeatureMatrix, k: usize, rng: &mut Pcg32) -> FeatureMatrix {
    let n = points.len();
    assert!(n > 0 && k > 0);
    let k = k.min(n);
    let mut centroids = FeatureMatrix::with_capacity(points.dim(), k);
    centroids.push_row(points.row(rng.below(n)));
    let mut d2: Vec<f32> =
        (0..n).map(|i| dist2(points.row(i), centroids.row(0))).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let next = if total <= 1e-30 {
            rng.below(n) // all points identical to some centroid
        } else {
            let mut u = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                u -= w as f64;
                if u <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        centroids.push_row(points.row(next));
        let c = centroids.row(centroids.len() - 1);
        for (i, dd) in d2.iter_mut().enumerate() {
            let nd = dist2(points.row(i), c);
            if nd < *dd {
                *dd = nd;
            }
        }
    }
    centroids
}

/// Lloyd iterations from given seed centroids. `par_threads > 1` lets the
/// per-point assignment sweep parallelize once the workload is large
/// enough; results are bit-identical either way.
pub(crate) fn lloyd(
    points: &FeatureMatrix,
    mut centroids: FeatureMatrix,
    max_iters: usize,
    par_threads: usize,
) -> KMeansResult {
    let n = points.len();
    let d = points.dim();
    let k = centroids.len();
    let mut assignment = vec![0u32; n];
    let mut nearest = vec![(0u32, 0.0f32); n]; // scratch: (cluster, dist2)
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0usize; k];
    let mut loss = 0.0f64;
    let parallel = par_threads > 1 && n * k * d >= gate(PAR_ASSIGN_MIN_WORK);
    for _ in 0..max_iters {
        // assignment sweep: per-point independent
        {
            let cent = &centroids;
            let assign_one = |i: usize, slot: &mut (u32, f32)| {
                let p = points.row(i);
                let mut bj = 0u32;
                let mut bd = f32::INFINITY;
                for j in 0..cent.len() {
                    let dd = dist2(p, cent.row(j));
                    if dd < bd {
                        bd = dd;
                        bj = j as u32;
                    }
                }
                *slot = (bj, bd);
            };
            if parallel {
                par_indexed_mut(&mut nearest, par_threads, assign_one);
            } else {
                for (i, slot) in nearest.iter_mut().enumerate() {
                    assign_one(i, slot);
                }
            }
        }
        // fold in point order (the serial order — keeps loss bit-identical)
        loss = 0.0;
        let mut moved = false;
        for (a, &(bj, bd)) in assignment.iter_mut().zip(&nearest) {
            if *a != bj {
                *a = bj;
                moved = true;
            }
            loss += bd as f64;
        }
        if !moved {
            break;
        }
        // update: per-cluster accumulation in point order (serial — the
        // fold order is the determinism contract; this is O(n·d), dwarfed
        // by the O(n·k·d) assignment above)
        sums.fill(0.0);
        counts.fill(0);
        for i in 0..n {
            let a = assignment[i] as usize;
            counts[a] += 1;
            for (s, &v) in sums[a * d..(a + 1) * d].iter_mut().zip(points.row(i)) {
                *s += v as f64;
            }
        }
        for j in 0..k {
            if counts[j] > 0 {
                let row = centroids.row_mut(j);
                for (cv, s) in row.iter_mut().zip(&sums[j * d..(j + 1) * d]) {
                    *cv = (s / counts[j] as f64) as f32;
                }
            }
            // empty cluster: leave centroid in place (will likely capture
            // points next iteration or stay harmless)
        }
    }

    KMeansResult { centroids, assignment, loss }
}

/// Run k-means with k-means++ seeding on a flat point matrix.
pub fn kmeans_matrix(
    points: &FeatureMatrix,
    k: usize,
    rng: &mut Pcg32,
    max_iters: usize,
) -> KMeansResult {
    let centroids = seed_centroids(points, k, rng);
    lloyd(points, centroids, max_iters, threads())
}

/// Run k-means with k-means++ seeding. `points` is row-major (n x d)
/// (compat shim over [`kmeans_matrix`]).
pub fn kmeans(points: &[Vec<f32>], k: usize, rng: &mut Pcg32, max_iters: usize) -> KMeansResult {
    assert!(!points.is_empty());
    kmeans_matrix(&FeatureMatrix::from_rows(points[0].len(), points), k, rng, max_iters)
}

/// Index of the input point nearest to each centroid (centroids are means,
/// not actual configurations; the sampler must measure real points).
pub fn nearest_points(points: &FeatureMatrix, centroids: &FeatureMatrix) -> Vec<usize> {
    (0..centroids.len())
        .map(|j| {
            let c = centroids.row(j);
            let mut best = 0;
            let mut bd = f32::INFINITY;
            for i in 0..points.len() {
                let dd = dist2(points.row(i), c);
                if dd < bd {
                    bd = dd;
                    best = i;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn blobs(rng: &mut Pcg32, k: usize, per: usize, d: usize, spread: f32) -> (Vec<Vec<f32>>, Vec<u32>) {
        let mut pts = Vec::new();
        let mut labels = Vec::new();
        for c in 0..k {
            let center: Vec<f32> = (0..d).map(|_| c as f32 * 10.0 + rng.f32()).collect();
            for _ in 0..per {
                pts.push(center.iter().map(|&v| v + rng.normal() as f32 * spread).collect());
                labels.push(c as u32);
            }
        }
        (pts, labels)
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let mut rng = Pcg32::seed_from(0);
        let (pts, labels) = blobs(&mut rng, 4, 50, 5, 0.2);
        let r = kmeans(&pts, 4, &mut rng, 50);
        // same-label points should share a cluster
        for c in 0..4 {
            let assigned: Vec<u32> = (0..200)
                .filter(|&i| labels[i] == c)
                .map(|i| r.assignment[i])
                .collect();
            assert!(assigned.iter().all(|&a| a == assigned[0]), "cluster {c} split");
        }
        assert!(r.loss < 200.0 * 5.0 * 0.2 * 0.2 * 4.0, "loss {}", r.loss);
    }

    #[test]
    fn loss_decreases_with_k() {
        let mut rng = Pcg32::seed_from(1);
        let (pts, _) = blobs(&mut rng, 6, 40, 8, 1.0);
        let l2 = kmeans(&pts, 2, &mut rng, 30).loss;
        let l6 = kmeans(&pts, 6, &mut rng, 30).loss;
        let l24 = kmeans(&pts, 24, &mut rng, 30).loss;
        assert!(l2 > l6 && l6 > l24, "{l2} {l6} {l24}");
    }

    #[test]
    fn k_ge_n_gives_zero_loss() {
        let mut rng = Pcg32::seed_from(2);
        let pts: Vec<Vec<f32>> = (0..10).map(|i| vec![i as f32, 0.0]).collect();
        let r = kmeans(&pts, 32, &mut rng, 10);
        assert!(r.loss < 1e-9);
        assert_eq!(r.centroids.len(), 10); // clamped to n
    }

    #[test]
    fn assignment_is_nearest_centroid_property() {
        forall(30, 0xca11, |rng| {
            let n = 30 + rng.below(100);
            let d = 2 + rng.below(6);
            let pts: Vec<Vec<f32>> =
                (0..n).map(|_| (0..d).map(|_| rng.f32()).collect()).collect();
            let k = 2 + rng.below(8);
            let r = kmeans(&pts, k, rng, 25);
            for (p, &a) in pts.iter().zip(&r.assignment) {
                let da = dist2(p, r.centroids.row(a as usize));
                for j in 0..r.centroids.len() {
                    assert!(da <= dist2(p, r.centroids.row(j)) + 1e-4);
                }
            }
        });
    }

    #[test]
    fn nearest_points_returns_members() {
        let mut rng = Pcg32::seed_from(3);
        let (pts, _) = blobs(&mut rng, 3, 30, 4, 0.3);
        let r = kmeans(&pts, 3, &mut rng, 30);
        let m = FeatureMatrix::from_rows(4, &pts);
        let near = nearest_points(&m, &r.centroids);
        assert_eq!(near.len(), 3);
        for (j, &i) in near.iter().enumerate() {
            // the chosen point must belong to that centroid's cluster
            assert_eq!(r.assignment[i], j as u32);
        }
    }

    #[test]
    fn identical_points_dont_crash() {
        let mut rng = Pcg32::seed_from(4);
        let pts = vec![vec![1.0f32, 2.0]; 20];
        let r = kmeans(&pts, 5, &mut rng, 10);
        assert!(r.loss < 1e-12);
    }

    #[test]
    fn parallel_assignment_is_bit_identical_to_serial() {
        // big enough that n*k*d crosses the parallel threshold
        let mut rng = Pcg32::seed_from(7);
        let (pts, _) = blobs(&mut rng, 8, 300, 6, 1.5);
        let m = FeatureMatrix::from_rows(6, &pts);
        assert!(m.len() * 16 * 6 >= PAR_ASSIGN_MIN_WORK);
        let mut rng_a = Pcg32::seed_from(8);
        let mut rng_b = Pcg32::seed_from(8);
        let seeds_a = seed_centroids(&m, 16, &mut rng_a);
        let seeds_b = seed_centroids(&m, 16, &mut rng_b);
        let serial = lloyd(&m, seeds_a, 25, 1);
        let par = lloyd(&m, seeds_b, 25, 4);
        assert_eq!(serial.loss.to_bits(), par.loss.to_bits());
        assert_eq!(serial.assignment, par.assignment);
        for j in 0..serial.centroids.len() {
            for (a, b) in serial.centroids.row(j).iter().zip(par.centroids.row(j)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // and the seeding consumed the same RNG draws
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }
}
