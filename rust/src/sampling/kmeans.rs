//! k-means (k-means++ init + Lloyd iterations) over normalized
//! configuration coordinates — the engine of the adaptive sampling module
//! (paper Algorithm 1). This is a hot path: it runs for every k in the
//! knee sweep, every tuning iteration.

use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct KMeansResult {
    /// k centroids, each a d-vector.
    pub centroids: Vec<Vec<f32>>,
    /// Cluster assignment per input point.
    pub assignment: Vec<u32>,
    /// Total within-cluster sum of squared distances ("Loss" in Alg. 1).
    pub loss: f64,
}

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Run k-means with k-means++ seeding. `points` is row-major (n x d).
pub fn kmeans(points: &[Vec<f32>], k: usize, rng: &mut Pcg32, max_iters: usize) -> KMeansResult {
    let n = points.len();
    assert!(n > 0 && k > 0);
    let k = k.min(n);
    let d = points[0].len();

    // --- k-means++ seeding --------------------------------------------------
    let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(k);
    centroids.push(points[rng.below(n)].clone());
    let mut d2: Vec<f32> = points.iter().map(|p| dist2(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let next = if total <= 1e-30 {
            rng.below(n) // all points identical to some centroid
        } else {
            let mut u = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                u -= w as f64;
                if u <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        centroids.push(points[next].clone());
        let c = centroids.last().unwrap();
        for (i, p) in points.iter().enumerate() {
            let nd = dist2(p, c);
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }

    // --- Lloyd iterations ---------------------------------------------------
    let mut assignment = vec![0u32; n];
    let mut loss = 0.0f64;
    for _ in 0..max_iters {
        // assign
        loss = 0.0;
        let mut moved = false;
        for (i, p) in points.iter().enumerate() {
            let mut best = 0u32;
            let mut bd = f32::INFINITY;
            for (j, c) in centroids.iter().enumerate() {
                let dd = dist2(p, c);
                if dd < bd {
                    bd = dd;
                    best = j as u32;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                moved = true;
            }
            loss += bd as f64;
        }
        if !moved {
            break;
        }
        // update
        let mut sums = vec![vec![0.0f64; d]; centroids.len()];
        let mut counts = vec![0usize; centroids.len()];
        for (p, &a) in points.iter().zip(&assignment) {
            counts[a as usize] += 1;
            for (s, &v) in sums[a as usize].iter_mut().zip(p) {
                *s += v as f64;
            }
        }
        for (j, c) in centroids.iter_mut().enumerate() {
            if counts[j] > 0 {
                for (cv, s) in c.iter_mut().zip(&sums[j]) {
                    *cv = (s / counts[j] as f64) as f32;
                }
            }
            // empty cluster: leave centroid in place (will likely capture
            // points next iteration or stay harmless)
        }
    }

    KMeansResult { centroids, assignment, loss }
}

/// Index of the input point nearest to each centroid (centroids are means,
/// not actual configurations; the sampler must measure real points).
pub fn nearest_points(points: &[Vec<f32>], centroids: &[Vec<f32>]) -> Vec<usize> {
    centroids
        .iter()
        .map(|c| {
            let mut best = 0;
            let mut bd = f32::INFINITY;
            for (i, p) in points.iter().enumerate() {
                let dd = dist2(p, c);
                if dd < bd {
                    bd = dd;
                    best = i;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn blobs(rng: &mut Pcg32, k: usize, per: usize, d: usize, spread: f32) -> (Vec<Vec<f32>>, Vec<u32>) {
        let mut pts = Vec::new();
        let mut labels = Vec::new();
        for c in 0..k {
            let center: Vec<f32> = (0..d).map(|_| c as f32 * 10.0 + rng.f32()).collect();
            for _ in 0..per {
                pts.push(center.iter().map(|&v| v + rng.normal() as f32 * spread).collect());
                labels.push(c as u32);
            }
        }
        (pts, labels)
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let mut rng = Pcg32::seed_from(0);
        let (pts, labels) = blobs(&mut rng, 4, 50, 5, 0.2);
        let r = kmeans(&pts, 4, &mut rng, 50);
        // same-label points should share a cluster
        for c in 0..4 {
            let assigned: Vec<u32> = (0..200)
                .filter(|&i| labels[i] == c)
                .map(|i| r.assignment[i])
                .collect();
            assert!(assigned.iter().all(|&a| a == assigned[0]), "cluster {c} split");
        }
        assert!(r.loss < 200.0 * 5.0 * 0.2 * 0.2 * 4.0, "loss {}", r.loss);
    }

    #[test]
    fn loss_decreases_with_k() {
        let mut rng = Pcg32::seed_from(1);
        let (pts, _) = blobs(&mut rng, 6, 40, 8, 1.0);
        let l2 = kmeans(&pts, 2, &mut rng, 30).loss;
        let l6 = kmeans(&pts, 6, &mut rng, 30).loss;
        let l24 = kmeans(&pts, 24, &mut rng, 30).loss;
        assert!(l2 > l6 && l6 > l24, "{l2} {l6} {l24}");
    }

    #[test]
    fn k_ge_n_gives_zero_loss() {
        let mut rng = Pcg32::seed_from(2);
        let pts: Vec<Vec<f32>> = (0..10).map(|i| vec![i as f32, 0.0]).collect();
        let r = kmeans(&pts, 32, &mut rng, 10);
        assert!(r.loss < 1e-9);
        assert_eq!(r.centroids.len(), 10); // clamped to n
    }

    #[test]
    fn assignment_is_nearest_centroid_property() {
        forall(30, 0xca11, |rng| {
            let n = 30 + rng.below(100);
            let d = 2 + rng.below(6);
            let pts: Vec<Vec<f32>> =
                (0..n).map(|_| (0..d).map(|_| rng.f32()).collect()).collect();
            let k = 2 + rng.below(8);
            let r = kmeans(&pts, k, rng, 25);
            for (p, &a) in pts.iter().zip(&r.assignment) {
                let da = dist2(p, &r.centroids[a as usize]);
                for c in &r.centroids {
                    assert!(da <= dist2(p, c) + 1e-4);
                }
            }
        });
    }

    #[test]
    fn nearest_points_returns_members() {
        let mut rng = Pcg32::seed_from(3);
        let (pts, _) = blobs(&mut rng, 3, 30, 4, 0.3);
        let r = kmeans(&pts, 3, &mut rng, 30);
        let near = nearest_points(&pts, &r.centroids);
        assert_eq!(near.len(), 3);
        for (j, &i) in near.iter().enumerate() {
            // the chosen point must belong to that centroid's cluster
            assert_eq!(r.assignment[i], j as u32);
        }
    }

    #[test]
    fn identical_points_dont_crash() {
        let mut rng = Pcg32::seed_from(4);
        let pts = vec![vec![1.0f32, 2.0]; 20];
        let r = kmeans(&pts, 5, &mut rng, 10);
        assert!(r.loss < 1e-12);
    }
}
