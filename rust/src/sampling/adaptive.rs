//! Adaptive Sampling (paper §4.2, Algorithm 1).
//!
//! Given the search agent's trajectory s_Θ, cluster it with k-means,
//! sweeping k ∈ [8, 64) and stopping at the knee of the loss curve
//! (`KNEE_CONSTANT x Loss > PreviousLoss`). The centroids become the
//! configurations measured on hardware; centroids that were already
//! visited (v_Θ) are replaced by the per-dimension *mode* configuration of
//! the trajectory — removing redundancy while maximizing the information
//! H_Θ of the sample set.

use super::fill_random_unvisited;
use super::kmeans::{kmeans_matrix, lloyd, nearest_points, seed_centroids};
use crate::space::{Config, DesignSpace};
use crate::util::matrix::FeatureMatrix;
use crate::util::parallel::{gate, par_map, threads};
use crate::util::rng::Pcg32;
use std::collections::BTreeSet;

/// `Constant` in Algorithm 1 line 7: break when Constant*Loss > PreviousLoss,
/// i.e. when adding ~8 more clusters no longer cuts the loss by >1/Constant.
pub const KNEE_CONSTANT: f64 = 1.4;
pub const K_MIN: usize = 8;
pub const K_MAX: usize = 64;
pub const K_STEP: usize = 8;

#[derive(Debug, Clone)]
pub struct AdaptiveSampleResult {
    pub samples: Vec<Config>,
    /// k chosen at the knee.
    pub k: usize,
    /// how many visited centroids were replaced by mode-configs.
    pub replaced: usize,
}

/// Lloyd iterations per kmeans call in the sweep.
const SWEEP_ITERS: usize = 25;

/// Below this points x dims size the sweep stays serial (speculating the
/// post-knee k's would cost more than it saves; [`gate`] scales it back up
/// under the scoped dispatch). Thread-count independent.
const PAR_SWEEP_MIN_WORK: usize = 1 << 7;

/// Sweep k over [K_MIN, K_MAX) in K_STEP strides; return the chosen k-means
/// clustering at the knee of the loss curve.
///
/// §Perf: with multiple worker threads, the sweep *speculates*: the
/// k-means++ seedings (the only RNG consumers) run serially in k order,
/// then every k's Lloyd phase — ~[`SWEEP_ITERS`]x the work — runs in
/// parallel. The knee rule is replayed over the losses, and the RNG is
/// restored to the state it would have had when the serial early-breaking
/// sweep stopped — so results *and* the RNG stream are bit-identical to
/// the serial path at any thread count; only wall-clock changes.
fn knee_kmeans(points: &FeatureMatrix, rng: &mut Pcg32) -> (usize, super::kmeans::KMeansResult) {
    let nthreads = threads();
    if nthreads <= 1 || points.len() * points.dim() < gate(PAR_SWEEP_MIN_WORK) {
        // the reference semantics: serial early-breaking sweep
        let mut prev_loss = f64::INFINITY;
        let mut chosen = None;
        let mut k = K_MIN;
        while k < K_MAX {
            let r = kmeans_matrix(points, k, rng, SWEEP_ITERS);
            let loss = r.loss;
            if loss <= 1e-12 {
                // perfect clustering — no information left to resolve
                chosen = Some((k, r));
                break;
            }
            if chosen.is_some() && KNEE_CONSTANT * loss > prev_loss {
                // knee reached: keep previous k's result
                break;
            }
            chosen = Some((k, r));
            prev_loss = loss;
            k += K_STEP;
        }
        return chosen.expect("k sweep produced no clustering");
    }

    // speculative parallel sweep, in waves of two k's: each wave seeds its
    // k's serially (recording the RNG state after each — exactly the
    // stream the serial sweep consumes per attempted k, since Lloyd draws
    // nothing), then runs both Lloyd phases concurrently, splitting the
    // remaining threads into each one's assignment sweep. A width-2 wave
    // is never slower than running its two k's back to back, and the knee
    // rule replays between waves so no wave past the knee ever launches.
    let ks: Vec<usize> = (K_MIN..K_MAX).step_by(K_STEP).collect();
    let inner = (nthreads / 2).max(1);
    let mut seeded: Vec<(usize, FeatureMatrix, Pcg32)> = Vec::new();
    let mut results: Vec<super::kmeans::KMeansResult> = Vec::new();
    let mut prev_loss = f64::INFINITY;
    let mut chosen: Option<usize> = None;
    let mut attempted = 0;
    'waves: for wave_ks in ks.chunks(2) {
        let start = seeded.len();
        for &k in wave_ks {
            let c = seed_centroids(points, k, rng);
            seeded.push((k, c, rng.clone()));
        }
        let wave = par_map(&seeded[start..], 2, |(_, c, _)| {
            lloyd(points, c.clone(), SWEEP_ITERS, inner)
        });
        // replay the serial knee rule over this wave's losses
        for r in wave {
            results.push(r);
            let i = results.len() - 1;
            attempted = i;
            let loss = results[i].loss;
            if loss <= 1e-12 {
                // perfect clustering — no information left to resolve
                chosen = Some(i);
                break 'waves;
            }
            if chosen.is_some() && KNEE_CONSTANT * loss > prev_loss {
                // knee reached: keep previous k's result
                break 'waves;
            }
            chosen = Some(i);
            prev_loss = loss;
        }
    }
    // the serial sweep would have stopped after attempting `attempted`:
    // restore its RNG state, discarding the speculative draws
    *rng = seeded[attempted].2.clone();
    let i = chosen.expect("k sweep produced no clustering");
    (seeded[i].0, results.swap_remove(i))
}

/// The per-dimension mode of the trajectory ("configuration generated from
/// modes of each dimension", Alg. 1 line 16).
pub fn mode_config(space: &DesignSpace, trajectory: &[Config]) -> Config {
    let idx = (0..space.ndims())
        .map(|d| {
            let mut counts = vec![0u32; space.knobs[d].len()];
            for c in trajectory {
                counts[c.idx[d] as usize] += 1;
            }
            let mut best = 0;
            for i in 1..counts.len() {
                if counts[i] > counts[best] {
                    best = i;
                }
            }
            best as u16
        })
        .collect();
    Config::new(idx)
}

/// Algorithm 1: ADAPTIVESAMPLING(s_Θ, v_Θ).
pub fn adaptive_sample(
    space: &DesignSpace,
    trajectory: &[Config],
    visited: &BTreeSet<u64>,
    rng: &mut Pcg32,
) -> AdaptiveSampleResult {
    assert!(!trajectory.is_empty());
    let mut points = FeatureMatrix::with_capacity(space.ndims(), trajectory.len());
    for c in trajectory {
        points.push_row_with(|out| space.normalize_into(c, out));
    }

    let (k, clustering) = knee_kmeans(&points, rng);

    // Centroids are means in R^8 — snap each to the nearest real trajectory
    // point (a measurable configuration).
    let nearest = nearest_points(&points, &clustering.centroids);
    let mut samples: Vec<Config> = Vec::with_capacity(nearest.len());
    let mut taken = BTreeSet::new();
    let mut replaced = 0;

    let mode = mode_config(space, trajectory);

    for i in nearest {
        let mut cand = trajectory[i].clone();
        let mut flat = space.flat_index(&cand);
        if visited.contains(&flat) || taken.contains(&flat) {
            // replace a redundant centroid with the mode configuration,
            // perturbing while still redundant (keeps exploration alive)
            cand = mode.clone();
            flat = space.flat_index(&cand);
            let mut guard = 0;
            while (visited.contains(&flat) || taken.contains(&flat)) && guard < 64 {
                cand = space.mutate(&cand, rng);
                flat = space.flat_index(&cand);
                guard += 1;
            }
            if visited.contains(&flat) || taken.contains(&flat) {
                continue; // give up on this centroid
            }
            replaced += 1;
        }
        taken.insert(flat);
        samples.push(cand);
    }

    if samples.is_empty() {
        // Every centroid was already visited and every mode-perturbation
        // collided. Returning nothing would make the tuner abandon its
        // remaining measurement budget, so fall back to unvisited
        // uniform-random configs (the guard keeps a truly exhausted space
        // from spinning; only then may the result stay empty).
        fill_random_unvisited(space, visited, &mut taken, k, 4096, rng, &mut samples);
    }

    crate::obs::metrics::inc(crate::obs::metrics::Counter::AdaptiveSamples);
    crate::obs::emit_ctx(
        "sample",
        "adaptive",
        crate::obs::ctx_base(),
        0,
        &[
            ("k", k as f64),
            ("replaced", replaced as f64),
            ("n", samples.len() as f64),
        ],
    );
    AdaptiveSampleResult { samples, k, replaced }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::workload::zoo;

    fn space() -> DesignSpace {
        DesignSpace::for_conv(zoo::resnet18()[1].layer)
    }

    fn random_trajectory(space: &DesignSpace, n: usize, rng: &mut Pcg32) -> Vec<Config> {
        (0..n).map(|_| space.random_config(rng)).collect()
    }

    /// A trajectory concentrated around `m` cluster centers — the structure
    /// the paper observes in Figure 3.
    fn clustered_trajectory(space: &DesignSpace, m: usize, per: usize, rng: &mut Pcg32) -> Vec<Config> {
        let mut t = Vec::new();
        for _ in 0..m {
            let center = space.random_config(rng);
            for _ in 0..per {
                let mut c = center.clone();
                // jitter the wide knobs by ±1; keep small categorical knobs
                // cluster-pure (what converging search trajectories look like)
                for d in 0..space.ndims() {
                    let len = space.knobs[d].len() as i32;
                    if len > 8 {
                        let j = (c.idx[d] as i32 + rng.below(3) as i32 - 1)
                            .clamp(0, len - 1);
                        c.idx[d] = j as u16;
                    }
                }
                t.push(c);
            }
        }
        t
    }

    #[test]
    fn reduces_measurements_below_trajectory_size() {
        let s = space();
        let mut rng = Pcg32::seed_from(0);
        let traj = random_trajectory(&s, 512, &mut rng);
        let r = adaptive_sample(&s, &traj, &BTreeSet::new(), &mut rng);
        assert!(r.samples.len() <= K_MAX);
        assert!(r.samples.len() >= K_MIN / 2);
        assert!(r.samples.len() < traj.len() / 4);
    }

    #[test]
    fn knee_picks_small_k_for_clustered_data() {
        let s = space();
        let mut rng = Pcg32::seed_from(1);
        let traj = clustered_trajectory(&s, 6, 60, &mut rng);
        let r = adaptive_sample(&s, &traj, &BTreeSet::new(), &mut rng);
        // 6 true clusters: the sweep must hit the knee well before K_MAX
        assert!(r.k <= 40, "k = {}", r.k);

        // degenerate case: 6 exactly-repeated configs => perfect clustering
        // at K_MIN, the sweep must stop immediately
        let centers: Vec<Config> = (0..6).map(|_| s.random_config(&mut rng)).collect();
        let dup: Vec<Config> =
            (0..360).map(|i| centers[i % 6].clone()).collect();
        let rd = adaptive_sample(&s, &dup, &BTreeSet::new(), &mut rng);
        assert_eq!(rd.k, K_MIN, "duplicates should cluster perfectly at K_MIN");
    }

    #[test]
    fn samples_are_unique_and_unvisited() {
        let s = space();
        forall(20, 0xada, |rng| {
            let traj = random_trajectory(&s, 256, rng);
            // mark half the trajectory visited
            let visited: BTreeSet<u64> =
                traj.iter().take(128).map(|c| s.flat_index(c)).collect();
            let r = adaptive_sample(&s, &traj, &visited, rng);
            let mut seen = BTreeSet::new();
            for c in &r.samples {
                let f = s.flat_index(c);
                assert!(!visited.contains(&f), "returned a visited config");
                assert!(seen.insert(f), "duplicate sample");
            }
        });
    }

    #[test]
    fn visited_centroids_get_replaced_by_mode() {
        let s = space();
        let mut rng = Pcg32::seed_from(3);
        let traj = clustered_trajectory(&s, 4, 40, &mut rng);
        // visit everything in the trajectory => all centroids redundant
        let visited: BTreeSet<u64> = traj.iter().map(|c| s.flat_index(c)).collect();
        let r = adaptive_sample(&s, &traj, &visited, &mut rng);
        assert!(r.replaced > 0);
        for c in &r.samples {
            assert!(!visited.contains(&s.flat_index(c)));
        }
    }

    #[test]
    fn mode_config_is_per_dimension_majority() {
        let s = space();
        let mut a = Config::new(vec![1; 8]);
        a.idx[0] = 3;
        let b = Config::new(vec![1; 8]);
        let c = Config::new(vec![0; 8]);
        let m = mode_config(&s, &[a, b.clone(), b, c]);
        assert_eq!(m.idx[0], 1); // 1 appears twice, 3 once, 0 once
        assert_eq!(m.idx[1], 1);
    }

    #[test]
    fn empty_sample_falls_back_to_random_unvisited() {
        use crate::space::{Knob, KnobKind};
        use crate::workload::ConvLayer;
        // A deliberately tiny 4-point space (two binary knobs): the lone
        // centroid is visited and every single-knob perturbation of the mode
        // collides with the visited set, which used to return an empty
        // sample set and make the tuner abandon its remaining budget.
        let layer = ConvLayer::new(4, 8, 8, 4, 1, 1, 1, 0);
        let kinds = [
            KnobKind::TileF,
            KnobKind::TileY,
            KnobKind::TileX,
            KnobKind::TileRC,
            KnobKind::TileRY,
            KnobKind::TileRX,
            KnobKind::AutoUnrollMaxStep,
            KnobKind::UnrollExplicit,
        ];
        let knobs: Vec<Knob> = kinds
            .iter()
            .enumerate()
            .map(|(d, &k)| Knob::new(k, if d < 2 { vec![0, 1] } else { vec![0] }))
            .collect();
        let s = DesignSpace { layer, knobs };
        let a = Config::new(vec![0; 8]);
        let mut b = a.clone();
        b.idx[1] = 1;
        let mut c = a.clone();
        c.idx[0] = 1;
        let visited: BTreeSet<u64> =
            [&a, &b, &c].iter().map(|cc| s.flat_index(cc)).collect();
        let traj = vec![a; 16];
        let mut rng = Pcg32::seed_from(7);
        let r = adaptive_sample(&s, &traj, &visited, &mut rng);
        assert_eq!(r.samples.len(), 1, "exactly one unvisited config exists");
        assert!(!visited.contains(&s.flat_index(&r.samples[0])));
    }

    #[test]
    fn speculative_sweep_matches_serial_results_and_rng_stream() {
        // the knee sweep's parallel speculation must leave both the chosen
        // clustering AND the caller's RNG exactly where the serial sweep
        // would — across clustered, random and degenerate trajectories
        let s = space();
        let mut gen = Pcg32::seed_from(0x5eed);
        let trajs = vec![
            random_trajectory(&s, 300, &mut gen),
            clustered_trajectory(&s, 5, 50, &mut gen),
            (0..200)
                .map(|i| {
                    let v = (i % 2) as u16;
                    Config::new(vec![v; 8])
                })
                .collect(),
        ];
        let _knob = crate::util::parallel::thread_knob_guard();
        for (t, traj) in trajs.iter().enumerate() {
            crate::util::parallel::set_threads(1);
            let mut rng_a = Pcg32::seed_from(42 + t as u64);
            let ra = adaptive_sample(&s, traj, &BTreeSet::new(), &mut rng_a);
            crate::util::parallel::set_threads(4);
            let mut rng_b = Pcg32::seed_from(42 + t as u64);
            let rb = adaptive_sample(&s, traj, &BTreeSet::new(), &mut rng_b);
            crate::util::parallel::set_threads(0);
            assert_eq!(ra.k, rb.k, "traj {t}");
            assert_eq!(ra.replaced, rb.replaced, "traj {t}");
            assert_eq!(ra.samples, rb.samples, "traj {t}");
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "rng stream diverged");
        }
    }

    #[test]
    fn single_point_trajectory() {
        let s = space();
        let mut rng = Pcg32::seed_from(4);
        let traj = vec![s.random_config(&mut rng)];
        let r = adaptive_sample(&s, &traj, &BTreeSet::new(), &mut rng);
        assert_eq!(r.samples.len(), 1);
    }
}
