//! AutoTVM's baseline sampler: ε-greedy top-`plan_size` selection over the
//! cost model's predicted scores (Chen et al., 2018b). The paper's Fig 6
//! compares adaptive sampling against exactly this policy.

use super::fill_random_unvisited;
use crate::space::{Config, DesignSpace};
use crate::util::rng::Pcg32;
use std::collections::BTreeSet;

pub const DEFAULT_PLAN_SIZE: usize = 64;
pub const DEFAULT_EPSILON: f64 = 0.05;

/// Pick up to `plan_size` configs: the top-scored unvisited trajectory
/// points, with an ε fraction replaced by random unvisited configs
/// (AutoTVM's epsilon-greedy exploration).
pub fn greedy_sample(
    space: &DesignSpace,
    trajectory: &[Config],
    scores: &[f64],
    visited: &BTreeSet<u64>,
    plan_size: usize,
    epsilon: f64,
    rng: &mut Pcg32,
) -> Vec<Config> {
    assert_eq!(trajectory.len(), scores.len());
    let mut order: Vec<usize> = (0..trajectory.len()).collect();
    // a NaN score (poisoned model output) must neither panic the sampler
    // nor win an exploitation slot: rank it like the worst possible score
    let key = |v: f64| if v.is_nan() { f64::NEG_INFINITY } else { v };
    order.sort_by(|&a, &b| key(scores[b]).total_cmp(&key(scores[a])));

    let n_random = ((plan_size as f64 * epsilon).round() as usize).min(plan_size);
    let n_top = plan_size - n_random;

    let mut out = Vec::with_capacity(plan_size);
    let mut taken: BTreeSet<u64> = BTreeSet::new();
    for &i in &order {
        if out.len() >= n_top {
            break;
        }
        let flat = space.flat_index(&trajectory[i]);
        if visited.contains(&flat) || !taken.insert(flat) {
            continue;
        }
        out.push(trajectory[i].clone());
    }
    // ε exploration: uniform random unvisited configs from the full space
    let want = plan_size - out.len();
    fill_random_unvisited(space, visited, &mut taken, want, plan_size * 100, rng, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    fn space() -> DesignSpace {
        DesignSpace::for_conv(zoo::vgg16()[4].layer)
    }

    #[test]
    fn takes_top_scored_first() {
        let s = space();
        let mut rng = Pcg32::seed_from(0);
        let traj: Vec<Config> = (0..100).map(|_| s.random_config(&mut rng)).collect();
        let scores: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let out = greedy_sample(&s, &traj, &scores, &BTreeSet::new(), 8, 0.0, &mut rng);
        assert_eq!(out.len(), 8);
        // highest scores are at the end of traj
        let top: BTreeSet<u64> =
            traj[92..].iter().map(|c| s.flat_index(c)).collect();
        let got: BTreeSet<u64> = out.iter().map(|c| s.flat_index(c)).collect();
        assert_eq!(top, got);
    }

    #[test]
    fn skips_visited() {
        let s = space();
        let mut rng = Pcg32::seed_from(1);
        let traj: Vec<Config> = (0..50).map(|_| s.random_config(&mut rng)).collect();
        let scores: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let visited: BTreeSet<u64> =
            traj[40..].iter().map(|c| s.flat_index(c)).collect();
        let out = greedy_sample(&s, &traj, &scores, &visited, 10, 0.0, &mut rng);
        for c in &out {
            assert!(!visited.contains(&s.flat_index(c)));
        }
    }

    #[test]
    fn epsilon_adds_random_exploration() {
        let s = space();
        let mut rng = Pcg32::seed_from(2);
        let traj: Vec<Config> = (0..64).map(|_| s.random_config(&mut rng)).collect();
        let scores = vec![1.0; 64];
        let out = greedy_sample(&s, &traj, &scores, &BTreeSet::new(), 64, 0.25, &mut rng);
        assert_eq!(out.len(), 64);
        let traj_set: BTreeSet<u64> = traj.iter().map(|c| s.flat_index(c)).collect();
        let fresh = out.iter().filter(|c| !traj_set.contains(&s.flat_index(c))).count();
        assert!(fresh >= 10, "only {fresh} random picks");
    }

    #[test]
    fn nan_scores_do_not_panic_or_win_slots() {
        // regression for the partial_cmp().unwrap() comparator: NaN must
        // neither panic nor displace the genuinely best-scored configs
        let s = space();
        let mut rng = Pcg32::seed_from(5);
        let traj: Vec<Config> = (0..32).map(|_| s.random_config(&mut rng)).collect();
        let mut scores: Vec<f64> = (0..32).map(|i| i as f64).collect();
        scores[3] = f64::NAN;
        scores[17] = f64::NAN;
        let out = greedy_sample(&s, &traj, &scores, &BTreeSet::new(), 10, 0.0, &mut rng);
        assert_eq!(out.len(), 10);
        let distinct: BTreeSet<u64> = out.iter().map(|c| s.flat_index(c)).collect();
        assert_eq!(distinct.len(), out.len());
        // the top-scored config still makes the cut; the NaN-scored ones
        // rank like the worst score and are left out
        let got: BTreeSet<u64> = out.iter().map(|c| s.flat_index(c)).collect();
        assert!(got.contains(&s.flat_index(&traj[31])));
        assert!(!got.contains(&s.flat_index(&traj[3])));
        assert!(!got.contains(&s.flat_index(&traj[17])));
    }

    #[test]
    fn dedupes_duplicate_trajectory_entries() {
        let s = space();
        let mut rng = Pcg32::seed_from(3);
        let c = s.random_config(&mut rng);
        let traj = vec![c.clone(); 20];
        let scores = vec![1.0; 20];
        let out = greedy_sample(&s, &traj, &scores, &BTreeSet::new(), 5, 0.0, &mut rng);
        // only one distinct trajectory point exists; rest come from ε-pool
        let distinct: BTreeSet<u64> = out.iter().map(|x| s.flat_index(x)).collect();
        assert_eq!(distinct.len(), out.len());
    }
}
