//! Sampling modules: which trajectory points get measured on hardware.
//!
//! - `greedy`: AutoTVM's ε-greedy top-plan_size baseline.
//! - `adaptive`: the paper's clustering-based Algorithm 1.

pub mod adaptive;
pub mod greedy;
pub mod kmeans;

pub use adaptive::{adaptive_sample, mode_config, AdaptiveSampleResult};
pub use greedy::{greedy_sample, DEFAULT_EPSILON, DEFAULT_PLAN_SIZE};
pub use kmeans::{kmeans, kmeans_matrix, nearest_points, KMeansResult};

use crate::space::{Config, DesignSpace};
use crate::util::rng::Pcg32;
use std::collections::BTreeSet;

/// Push up to `want` uniform-random configs onto `out`, skipping anything in
/// `visited` or `taken` (accepted configs are added to `taken`). Bounded by
/// `guard` draws so a nearly-exhausted space cannot spin forever. This is
/// the shared exploration / fallback pool of both samplers and the tuner's
/// ε-exploration share.
pub fn fill_random_unvisited(
    space: &DesignSpace,
    visited: &BTreeSet<u64>,
    taken: &mut BTreeSet<u64>,
    want: usize,
    guard: usize,
    rng: &mut Pcg32,
    out: &mut Vec<Config>,
) {
    let target = out.len() + want;
    let mut draws = 0;
    while out.len() < target && draws < guard {
        let c = space.random_config(rng);
        let flat = space.flat_index(&c);
        if !visited.contains(&flat) && taken.insert(flat) {
            out.push(c);
        }
        draws += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn fill_random_unvisited_respects_sets_and_guard() {
        let space = DesignSpace::for_conv(zoo::alexnet()[2].layer);
        let mut rng = Pcg32::seed_from(0);
        let mut taken = BTreeSet::new();
        let mut out = Vec::new();
        // pre-visit a handful of configs; draws must avoid them
        let visited: BTreeSet<u64> =
            (0..32).map(|_| space.flat_index(&space.random_config(&mut rng))).collect();
        fill_random_unvisited(&space, &visited, &mut taken, 16, 1000, &mut rng, &mut out);
        assert_eq!(out.len(), 16);
        let mut seen = BTreeSet::new();
        for c in &out {
            let f = space.flat_index(c);
            assert!(!visited.contains(&f));
            assert!(seen.insert(f), "duplicate config");
            assert!(taken.contains(&f));
        }
        // a zero guard adds nothing
        fill_random_unvisited(&space, &visited, &mut taken, 8, 0, &mut rng, &mut out);
        assert_eq!(out.len(), 16);
    }
}

/// Which sampler a tuner uses (paper ablations: Greedy vs Adaptive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    /// AutoTVM ε-greedy top-k.
    Greedy,
    /// RELEASE adaptive sampling (Algorithm 1).
    Adaptive,
}

impl std::fmt::Display for SamplerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SamplerKind::Greedy => write!(f, "greedy"),
            SamplerKind::Adaptive => write!(f, "adaptive"),
        }
    }
}
