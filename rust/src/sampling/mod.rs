//! Sampling modules: which trajectory points get measured on hardware.
//!
//! - `greedy`: AutoTVM's ε-greedy top-plan_size baseline.
//! - `adaptive`: the paper's clustering-based Algorithm 1.

pub mod adaptive;
pub mod greedy;
pub mod kmeans;

pub use adaptive::{adaptive_sample, mode_config, AdaptiveSampleResult};
pub use greedy::{greedy_sample, DEFAULT_EPSILON, DEFAULT_PLAN_SIZE};
pub use kmeans::{kmeans, nearest_points, KMeansResult};

/// Which sampler a tuner uses (paper ablations: Greedy vs Adaptive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    /// AutoTVM ε-greedy top-k.
    Greedy,
    /// RELEASE adaptive sampling (Algorithm 1).
    Adaptive,
}

impl std::fmt::Display for SamplerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SamplerKind::Greedy => write!(f, "greedy"),
            SamplerKind::Adaptive => write!(f, "adaptive"),
        }
    }
}
