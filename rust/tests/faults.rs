//! Fault-tolerance determinism (the fault-injection tentpole's pins):
//!
//!  1. `--faults off` is bit-identical to the fault-free pipeline at any
//!     `--threads` — the wrapper and retry layer add nothing when disabled.
//!  2. A fixed `--fault-seed` replays the exact same fault schedule —
//!     results AND chrome trace — at any `--threads`: faults are a pure
//!     function of (seed, config, attempt), never of host scheduling.
//!  3. A chaos session under the standard profile (2 lanes, 2 device
//!     slots) completes on the surviving slot, with quarantined configs
//!     and an ejected slot reported.
//!
//! The obs sink is process-global, so this binary keeps everything inside
//! one `#[test]` (same discipline as `rust/tests/trace.rs`).

mod common;

use common::{assert_tasks_bitwise_equal, measurer, quick_cfg_trials};
use release::obs;
use release::sim::{FaultConfig, FaultProfile};
use release::tuner::e2e::ModelTuneResult;
use release::tuner::session::{tune_model_session, SessionConfig};
use release::tuner::MethodSpec;
use release::util::parallel::{set_threads, thread_knob_guard};

fn faulted_scfg(threads: usize) -> SessionConfig {
    SessionConfig {
        tuner: quick_cfg_trials(11, 48),
        device_slots: 2,
        threads,
        faults: FaultConfig {
            profile: FaultProfile::Standard,
            fault_seed: 7,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn run(scfg: &SessionConfig) -> ModelTuneResult {
    tune_model_session("alexnet", &measurer(5), MethodSpec::sa_as(), scfg, None)
        .expect("session completes")
}

#[test]
fn fault_layer_is_deterministic_and_degrades_gracefully() {
    let _knob = thread_knob_guard();

    // --- 1. faults off: bit-identical to the bare pipeline at any --threads
    let base = SessionConfig {
        tuner: quick_cfg_trials(11, 48),
        threads: 1,
        ..Default::default()
    };
    let bare = run(&base);
    assert_eq!(bare.n_quarantined, 0);
    assert!(bare.ejected_slots.is_empty());
    assert!(bare.tasks.iter().all(|t| t
        .iterations
        .iter()
        .all(|it| it.slot_failures.is_empty() && it.quarantined == 0)));
    for threads in [2usize, 4] {
        let mut scfg = base.clone();
        scfg.threads = threads;
        assert_tasks_bitwise_equal(&bare, &run(&scfg));
    }

    // --- 2. fixed fault seed: bit-identical results at any --threads
    let a = run(&faulted_scfg(1));
    let b = run(&faulted_scfg(2));
    let c = run(&faulted_scfg(4));
    assert_tasks_bitwise_equal(&a, &b);
    assert_tasks_bitwise_equal(&a, &c);
    assert_eq!(a.n_quarantined, b.n_quarantined);
    assert_eq!(a.ejected_slots, b.ejected_slots);
    assert_eq!(a.ejected_slots, c.ejected_slots);
    // the fault plan actually fired — the pins above are not vacuous
    assert!(
        a.tasks
            .iter()
            .any(|t| t.iterations.iter().any(|it| !it.slot_failures.is_empty())),
        "standard profile at seed 7 recorded no slot failures"
    );

    // a different fault seed is a different (but equally valid) bad day
    let mut other = faulted_scfg(1);
    other.faults.fault_seed = 8;
    let d = run(&other);
    let same = a.n_quarantined == d.n_quarantined
        && a
            .tasks
            .iter()
            .zip(&d.tasks)
            .all(|(x, y)| x.best_runtime_ms.to_bits() == y.best_runtime_ms.to_bits());
    assert!(!same, "the fault seed must steer the fault plan");

    // --- 3. chaos completion: 2 lanes + 2 slots under standard faults ends
    // with quarantines, one ejected slot, and every task still tuned
    let mut chaos = faulted_scfg(1);
    chaos.tuner = quick_cfg_trials(3, 96);
    chaos.task_parallelism = 2;
    chaos.pipeline_depth = 2;
    let r = run(&chaos);
    for t in &r.tasks {
        assert!(t.best_gflops > 0.0, "{} found nothing under faults", t.task_id);
        assert!(t.best_runtime_ms.is_finite(), "{}", t.task_id);
    }
    assert!(r.n_quarantined > 0, "chaos run quarantined nothing");
    assert_eq!(r.ejected_slots.len(), 1, "{:?}", r.ejected_slots);
    assert!(r.wall_s > 0.0 && r.wall_s.is_finite());

    // --- trace determinism: same fault seed => byte-identical trace at
    // any --threads, with the retry + eject spans recorded
    let t1 = traced_faulted_run(1);
    let t2 = traced_faulted_run(2);
    let t4 = traced_faulted_run(4);
    set_threads(0);
    assert_eq!(t1, t2, "faulted trace diverges between threads 1 and 2");
    assert_eq!(t1, t4, "faulted trace diverges between threads 1 and 4");
    assert!(
        t1.contains("\"cat\":\"measure\",\"name\":\"retry\""),
        "retry spans missing from the faulted trace"
    );
    assert!(
        t1.contains("\"cat\":\"device\",\"name\":\"eject\""),
        "eject span missing from the faulted trace"
    );
}

/// One serial faulted session with tracing on; returns the chrome
/// rendering. Serial schedule: the trace contract covers deterministic
/// runs, and `--threads` must not perturb a single byte of it.
fn traced_faulted_run(threads: usize) -> String {
    let mut scfg = faulted_scfg(threads);
    scfg.task_parallelism = 1;
    obs::enable();
    let r = run(&scfg);
    obs::disable();
    assert_eq!(obs::dropped(), 0, "sink overflow would truncate the trace");
    assert!(r.n_measurements > 0);
    obs::render_chrome_jsonl(&obs::drain())
}
