//! Cross-module integration tests: the full tuner stack over the simulated
//! hardware, the four evaluation arms (RL on the native backend),
//! determinism, and clock accounting.
//!
//! Fixtures (tuner configs, measurers, backends, bitwise assertions) come
//! from the shared `common` harness.

mod common;

use common::{measurer, native_backend, quick_cfg, quick_cfg_trials};
use release::space::DesignSpace;
use release::tuner::session::{tune_tasks_session, SessionConfig};
use release::tuner::{e2e::tune_model, e2e::tune_tasks, tune, MethodSpec, TunerConfig};
use release::util::prop::forall;
use release::workload::zoo;

#[test]
fn all_non_rl_arms_tune_the_same_task() {
    let task = &zoo::resnet18()[5];
    for name in ["autotvm", "sa+as", "ga", "random"] {
        let method = MethodSpec::parse(name).unwrap();
        let meas = measurer(1);
        let r = tune(task, &meas, method, &quick_cfg(1), None);
        assert!(r.best_gflops > 0.0, "{name} found nothing");
        assert!(r.n_measurements <= 160, "{name} overspent");
        assert!(r.best_runtime_ms.is_finite());
        assert!(r.clock.measure_s > 0.0);
    }
}

#[test]
fn rl_arms_tune_end_to_end_on_the_native_backend() {
    // The paper's RL and RELEASE arms, no XLA artifacts anywhere: the
    // pure-Rust backend must carry a full tune loop per method.
    let task = &zoo::resnet18()[5];
    for name in ["rl", "release"] {
        let method = MethodSpec::parse(name).unwrap();
        let meas = measurer(1);
        let cfg = quick_cfg_trials(1, 96);
        let r = tune(task, &meas, method, &cfg, Some(native_backend()));
        assert!(r.best_gflops > 0.0, "{name} found nothing");
        assert!(r.n_measurements <= 96, "{name} overspent");
        assert!(r.best_runtime_ms.is_finite());
        assert!(r.clock.search_s > 0.0 && r.clock.measure_s > 0.0);
        // the Fig 5 metric is populated
        assert!(r.iterations.iter().all(|it| it.steps_to_converge <= it.steps));
    }
}

#[test]
fn session_engine_runs_rl_method_without_artifacts() {
    // The pipelined multi-task session engine with the RL method on the
    // native backend (the acceptance bar of PR 2's tentpole).
    let cfg = quick_cfg_trials(2, 48);
    let scfg = SessionConfig::pipelined(cfg, 2);
    let r = tune_tasks_session(
        "alexnet",
        &zoo::alexnet(),
        &measurer(3),
        MethodSpec::release(),
        &scfg,
        Some(native_backend()),
    );
    assert_eq!(r.tasks.len(), 5);
    for t in &r.tasks {
        assert!(t.best_gflops > 0.0, "{} found nothing", t.task_id);
        assert!(t.n_measurements <= 48);
    }
    assert!(r.inference_ms.is_finite() && r.inference_ms > 0.0);
    assert!(r.wall_s > 0.0 && r.wall_s <= r.opt_time_s + 1e-9);
}

#[test]
fn rl_beats_random_under_equal_trial_budget() {
    // PpoAgent smoke test: with the same measurement budget, the PPO agent
    // (cost-model-guided) must beat uniform random search on most seeds.
    let task = &zoo::alexnet()[3];
    let mut wins = 0;
    for seed in 0..3u64 {
        let meas_a = measurer(seed + 50);
        let meas_b = measurer(seed + 50);
        let cfg =
            TunerConfig { max_trials: 160, early_stop: None, seed, ..Default::default() };
        let rl = tune(task, &meas_a, MethodSpec::rl_only(), &cfg, Some(native_backend()));
        let rnd =
            tune(task, &meas_b, MethodSpec::parse("random").unwrap(), &cfg, None);
        if rl.best_gflops >= rnd.best_gflops {
            wins += 1;
        }
    }
    assert!(wins >= 2, "RL won only {wins}/3 against random");
}

#[test]
fn guided_search_beats_pure_random_on_average() {
    // With the same measurement budget, AutoTVM (model-guided SA) should
    // beat random search on most seeds — the premise of autotuning.
    let task = &zoo::vgg16()[6];
    let mut wins = 0;
    for seed in 0..5u64 {
        let meas_a = measurer(seed);
        let meas_b = measurer(seed);
        let cfg = TunerConfig { max_trials: 256, early_stop: None, seed, ..Default::default() };
        let guided = tune(task, &meas_a, MethodSpec::autotvm(), &cfg, None);
        let random =
            tune(task, &meas_b, MethodSpec::parse("random").unwrap(), &cfg, None);
        if guided.best_gflops >= random.best_gflops {
            wins += 1;
        }
    }
    assert!(wins >= 3, "guided won only {wins}/5");
}

#[test]
fn clock_is_monotone_and_dominated_by_measurement() {
    let task = &zoo::alexnet()[2];
    let meas = measurer(3);
    let cfg = TunerConfig { max_trials: 256, early_stop: None, seed: 3, ..Default::default() };
    let r = tune(task, &meas, MethodSpec::autotvm(), &cfg, None);
    let mut prev = 0.0;
    for it in &r.iterations {
        assert!(it.clock.total_s() >= prev);
        prev = it.clock.total_s();
    }
    let frac = r.clock.measure_fraction();
    assert!(frac > 0.5, "measurement fraction {frac}");
    // simulated device accounting matches the tuner's view
    use release::sim::Measurer as _;
    assert!((meas.elapsed_s() - r.clock.measure_s).abs() < 1e-6);
}

#[test]
fn adaptive_sampling_reduces_measurements_on_equal_convergence_policy() {
    let task = &zoo::resnet18()[8];
    let mut greedy_total = 0usize;
    let mut adaptive_total = 0usize;
    for seed in 0..3u64 {
        let cfg = quick_cfg_trials(seed, 512);
        let m1 = measurer(seed + 10);
        let m2 = measurer(seed + 10);
        // both arms use the same convergence policy; only the sampler differs
        greedy_total += tune(task, &m1, MethodSpec::autotvm(), &cfg, None).n_measurements;
        adaptive_total += tune(task, &m2, MethodSpec::sa_as(), &cfg, None).n_measurements;
    }
    assert!(
        adaptive_total < greedy_total,
        "adaptive {adaptive_total} !< greedy {greedy_total}"
    );
}

#[test]
fn e2e_model_tuning_aggregates_consistently() {
    let meas = measurer(4);
    let cfg = quick_cfg_trials(4, 96);
    let r = tune_model("alexnet", &meas, MethodSpec::sa_as(), &cfg, None);
    assert_eq!(r.tasks.len(), 5);
    let sum_s: f64 = r.tasks.iter().map(|t| t.clock.total_s()).sum();
    assert!((r.opt_time_s - sum_s).abs() < 1e-9);
    assert!(r.inference_ms > 0.0);
    // no transfer ran: every task tuned cold
    assert_eq!(r.n_warm_started(), 0);
    assert!(r.tasks.iter().all(|t| t.transfer.is_none()));
    // every task produced a valid config in its own space
    for (t, task) in r.tasks.iter().zip(zoo::alexnet()) {
        let space = DesignSpace::for_conv(task.layer);
        let c = t.best_config.as_ref().expect("has best");
        assert!(space.flat_index(c) < space.size());
    }
}

#[test]
fn tuning_is_reproducible_across_runs() {
    let task = &zoo::vgg16()[1];
    let run = || {
        let meas = measurer(99);
        tune(task, &meas, MethodSpec::sa_as(), &quick_cfg(7), None)
    };
    let a = run();
    let b = run();
    assert_eq!(a.best_runtime_ms, b.best_runtime_ms);
    assert_eq!(a.n_measurements, b.n_measurements);
    assert_eq!(a.iterations.len(), b.iterations.len());
}

#[test]
fn tune_never_exceeds_budget_property() {
    // property: whatever (method, seed, budget) combination drives the
    // tuner, it must never spend more hardware measurements than
    // cfg.max_trials — including the adaptive sampler's top-up paths
    let tasks = [zoo::alexnet()[2].clone(), zoo::resnet18()[5].clone()];
    let methods = ["autotvm", "sa+as", "ga", "random"];
    forall(8, 0xb06e7, |rng| {
        let task = &tasks[rng.below(tasks.len())];
        let method = MethodSpec::parse(methods[rng.below(methods.len())]).unwrap();
        let max_trials = 24 + rng.below(140);
        let seed = rng.next_u64();
        let cfg = TunerConfig { max_trials, seed, ..Default::default() };
        let meas = measurer(seed ^ 0x5eed);
        let r = tune(task, &meas, method, &cfg, None);
        assert!(
            r.n_measurements <= max_trials,
            "{} overspent: {} > {max_trials} (seed {seed})",
            method.name(),
            r.n_measurements
        );
        use release::sim::Measurer as _;
        assert_eq!(r.n_measurements, meas.count(), "device count disagrees");
    });
}

#[test]
fn session_with_unit_parallelism_reproduces_serial_exactly() {
    // the pipelined session engine at task_parallelism = 1 and pipeline
    // depth 1 must be bit-identical to the serial tune_tasks path
    let tasks = zoo::alexnet();
    let cfg = quick_cfg_trials(31, 72);
    let serial = tune_tasks(
        "alexnet",
        &tasks,
        &measurer(8),
        MethodSpec::sa_as(),
        &cfg,
        None,
    );
    let scfg = SessionConfig::serial(cfg);
    let sess = tune_tasks_session(
        "alexnet",
        &tasks,
        &measurer(8),
        MethodSpec::sa_as(),
        &scfg,
        None,
    );
    common::assert_tasks_bitwise_equal(&serial, &sess);
    // the serial schedule's replayed wall equals the resource sum (up to fp
    // association in the replay)
    let rel = (sess.wall_s - serial.opt_time_s).abs() / serial.opt_time_s;
    assert!(rel < 1e-9, "wall {} vs serial sum {}", sess.wall_s, serial.opt_time_s);
}

#[test]
fn different_measurement_seeds_change_results() {
    // the simulated "hardware" has measurement noise: a different seed is a
    // different day on the machine
    let task = &zoo::vgg16()[1];
    let a = tune(task, &measurer(1), MethodSpec::sa_as(), &quick_cfg(7), None);
    let b = tune(task, &measurer(2), MethodSpec::sa_as(), &quick_cfg(7), None);
    assert_ne!(a.best_runtime_ms, b.best_runtime_ms);
}
