//! Golden-trace determinism: the `pallas-trace` chrome export is pinned
//! byte-identical across `--threads 1/2/4` for a small session covering all
//! six method arms (plus a transfer-enabled leg), because span timestamps
//! come from the simulated clock and span order from deterministic
//! `(lane, seq)` keys — never from host timing.
//!
//! The obs sink is process-global, so this binary keeps everything inside
//! one `#[test]` (the harness would otherwise interleave enable/disable
//! cycles from concurrent tests).

mod common;

use common::{measurer, native_backend, quick_cfg_trials, sibling_tasks};
use release::obs;
use release::transfer::{TransferConfig, TransferMode};
use release::tuner::session::{
    tune_model_session_checkpointed, tune_tasks_session, CheckpointSpec, SessionConfig,
};
use release::tuner::MethodSpec;
use release::util::parallel::{set_threads, thread_knob_guard};

const ARMS: [(&str, bool); 6] = [
    ("autotvm", false),
    ("ga", false),
    ("random", false),
    ("sa+as", false),
    ("rl", true),
    ("release", true),
];

/// One full sweep at a fixed thread count: every arm runs a pipelined
/// 2-task-parallel session, plus a serial transfer-enabled leg; each leg's
/// trace is drained and rendered separately (lanes are task-indexed and
/// reused across legs) and the renderings concatenated.
fn traced_sweep(threads: usize) -> String {
    let tasks = sibling_tasks();
    let mut out = String::new();
    for (name, needs_backend) in ARMS {
        let method = MethodSpec::parse(name).expect(name);
        let scfg = SessionConfig {
            tuner: quick_cfg_trials(11, 48),
            task_parallelism: 2,
            device_slots: 2,
            pipeline_depth: 2,
            threads,
            ..Default::default()
        };
        obs::enable();
        let r = tune_tasks_session(
            "tiny",
            &tasks,
            &measurer(5),
            method,
            &scfg,
            needs_backend.then(native_backend),
        );
        obs::disable();
        assert_eq!(obs::dropped(), 0, "{name}: sink overflow would truncate the trace");
        assert!(r.n_measurements > 0, "{name}: nothing measured");
        out.push_str(&format!("== {name} ==\n"));
        out.push_str(&obs::render_chrome_jsonl(&obs::drain()));
    }
    // transfer leg: serial schedule (with task parallelism the donor set a
    // task sees depends on sibling completion order, which is real
    // nondeterminism — the trace contract only covers deterministic runs)
    let mut transfer = TransferConfig::off();
    transfer.mode = TransferMode::Model;
    let scfg = SessionConfig {
        tuner: quick_cfg_trials(11, 48),
        transfer,
        threads,
        ..Default::default()
    };
    obs::enable();
    let r = tune_tasks_session("tiny", &tasks, &measurer(5), MethodSpec::sa_as(), &scfg, None);
    obs::disable();
    assert_eq!(obs::dropped(), 0);
    assert!(r.n_measurements > 0);
    out.push_str("== sa+as/transfer ==\n");
    out.push_str(&obs::render_chrome_jsonl(&obs::drain()));
    out
}

fn assert_same_trace(label: &str, a: &str, b: &str) {
    if a == b {
        return;
    }
    for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
        assert_eq!(la, lb, "{label}: traces first diverge at line {}", i + 1);
    }
    panic!(
        "{label}: traces differ in length: {} vs {} lines",
        a.lines().count(),
        b.lines().count()
    );
}

#[test]
fn golden_trace_bit_identical_across_thread_counts() {
    let _knob = thread_knob_guard();
    let t1 = traced_sweep(1);
    let t2 = traced_sweep(2);
    let t4 = traced_sweep(4);
    set_threads(0);
    assert_same_trace("threads 1 vs 2", &t1, &t2);
    assert_same_trace("threads 1 vs 4", &t1, &t4);

    // the instrumented stages all actually recorded
    for needle in [
        "\"cat\":\"tuner\",\"name\":\"plan\"",
        "\"cat\":\"tuner\",\"name\":\"absorb\"",
        "\"cat\":\"model\",\"name\":\"refit\"",
        "\"cat\":\"measure\",\"name\":\"batch\"",
        "\"cat\":\"search\",\"name\":\"sa\"",
        "\"cat\":\"sample\",\"name\":\"adaptive\"",
        "\"cat\":\"rl\",\"name\":\"ppo_update\"",
        "\"cat\":\"device\",\"name\":\"service\"",
        "\"cat\":\"lane\",\"name\":\"finish\"",
        "\"cat\":\"session\",\"name\":\"schedule\"",
        "\"cat\":\"transfer\",\"name\":\"consult\"",
        "\"cat\":\"transfer\",\"name\":\"publish\"",
        "\"name\":\"thread_name\"",
    ] {
        assert!(t1.contains(needle), "expected span missing from trace: {needle}");
    }

    // the export parses back and summarizes (CLI `report trace` path)
    let body = t1.split("==").last().expect("transfer leg body");
    let events = obs::summary::parse_chrome_trace(body);
    assert!(!events.is_empty());
    let s = obs::summary::summarize(&events);
    assert_eq!(s.n_events, events.len());
    assert!(!s.per_stage.rows.is_empty() && !s.per_lane.rows.is_empty());

    // checkpoint/resume leg (same binary: the obs sink is process-global):
    // a resumed session's trace — restored spans plus the re-executed tail
    // — must be byte-identical to the uninterrupted checkpointed run's
    let (full_trace, resumed_trace) = traced_checkpoint_resume(1);
    assert_same_trace("checkpointed vs resumed", &full_trace, &resumed_trace);
    assert!(
        full_trace.contains("\"cat\":\"ckpt\",\"name\":\"save\""),
        "checkpoint saves must appear in the trace"
    );

    // same contract under the lane-parallel engine (ckpt/save spans are
    // suppressed there — they key on worker races — but every lane span is
    // simulated-clock-deterministic, so the renderings still match bitwise)
    let (full_tp2, resumed_tp2) = traced_checkpoint_resume(2);
    assert_same_trace("tp=2 checkpointed vs resumed", &full_tp2, &resumed_tp2);
    assert!(
        !full_tp2.contains("\"cat\":\"ckpt\",\"name\":\"save\""),
        "ckpt spans are worker-race-dependent and must be suppressed at tp>1"
    );
}

/// Run an alexnet session twice at the given task parallelism — once
/// end-to-end with checkpointing at a 2-round cadence, once resumed from
/// the snapshot the first run left behind — and return both renderings.
fn traced_checkpoint_resume(task_parallelism: usize) -> (String, String) {
    let path = std::env::temp_dir().join(format!(
        "release-trace-ckpt-tp{task_parallelism}-{}.snap",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let scfg = SessionConfig {
        tuner: quick_cfg_trials(11, 96),
        task_parallelism,
        device_slots: task_parallelism,
        threads: 2,
        ..Default::default()
    };
    let spec = CheckpointSpec::new(path.clone(), 2);
    obs::enable();
    let full = tune_model_session_checkpointed(
        "alexnet",
        &measurer(5),
        MethodSpec::sa_as(),
        &scfg,
        None,
        Some(&spec),
        None,
    )
    .expect("checkpointed session");
    obs::disable();
    assert_eq!(obs::dropped(), 0);
    let full_trace = obs::render_chrome_jsonl(&obs::drain());
    assert!(path.exists(), "cadence 2 wrote no checkpoint");

    obs::enable();
    let resumed = tune_model_session_checkpointed(
        "alexnet",
        &measurer(5),
        MethodSpec::sa_as(),
        &scfg,
        None,
        Some(&spec),
        Some(&path),
    )
    .expect("resumed session");
    obs::disable();
    assert_eq!(obs::dropped(), 0);
    let resumed_trace = obs::render_chrome_jsonl(&obs::drain());
    common::assert_tasks_bitwise_equal(&full, &resumed);
    let _ = std::fs::remove_file(&path);
    (full_trace, resumed_trace)
}
