//! Self-lint integration tests: `pallas-lint` run against this very repo,
//! plus an end-to-end ratchet exercise on a synthetic tree.
//!
//! The first test is the same check CI runs (`pallas-lint
//! --check-baseline`): the working tree must carry no determinism/safety
//! debt beyond the committed `LINT_BASELINE.json`, and the baseline may
//! only ever shrink.

use release::analysis::rules::{ALLOWLIST, RULES};
use release::analysis::{baseline, lint_tree};
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is the crate root, which is the repo root here
    // (Cargo.toml lives at the top level).
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn repo_has_no_unbaselined_lint_debt() {
    let root = repo_root();
    let report = lint_tree(&root).expect("lint_tree over the repo");
    assert!(report.files_scanned > 40, "suspiciously few files scanned: {}", report.files_scanned);

    let counts = baseline::counts_of(&report.findings);
    let committed = baseline::read(&root.join(baseline::BASELINE_PATH))
        .expect("LINT_BASELINE.json must exist and parse — run `pallas-lint --write-baseline`");
    let d = baseline::diff(&counts, &committed);

    let mut msg = String::new();
    for (key, cur, base) in &d.regressions {
        msg.push_str(&format!("\n  NEW debt {key}: {cur} violation(s), baseline allows {base}"));
        for f in report.findings.iter().filter(|f| f.key() == *key) {
            msg.push_str(&format!("\n    {}:{} [{}] {}", f.file, f.line, f.rule, f.message));
            msg.push_str(&format!("\n      fix: {}", f.hint));
        }
    }
    assert!(
        d.is_clean(),
        "pallas-lint found violations beyond LINT_BASELINE.json:{msg}\n\
         (fix the sites, allowlist with a justification, or — only for \
         pre-existing debt — regenerate the baseline)"
    );
}

#[test]
fn lint_baseline_is_wellformed_and_refers_to_real_files() {
    let root = repo_root();
    let committed = baseline::read(&root.join(baseline::BASELINE_PATH))
        .expect("LINT_BASELINE.json must exist and parse");
    let rule_ids: Vec<&str> = RULES.iter().map(|(id, _, _)| *id).collect();
    for (key, count) in &committed {
        let (file, rule) = key
            .rsplit_once('|')
            .unwrap_or_else(|| panic!("malformed baseline key {key:?} (want file|RULE)"));
        assert!(rule_ids.contains(&rule), "unknown rule id in baseline key {key:?}");
        assert!(
            root.join(file).is_file(),
            "baseline key {key:?} names a file that no longer exists — \
             run `pallas-lint --write-baseline` to drop it"
        );
        assert!(*count > 0, "zero-count baseline bucket {key:?} should be absent");
    }
}

#[test]
fn allowlist_entries_refer_to_real_files() {
    let root = repo_root();
    for e in ALLOWLIST {
        assert!(
            root.join(e.file_suffix).is_file(),
            "allowlist entry [{}] {} names a file that no longer exists",
            e.rule,
            e.file_suffix
        );
        assert!(!e.reason.is_empty(), "allowlist entry for {} has no justification", e.file_suffix);
    }
}

// ---- end-to-end ratchet on a synthetic tree --------------------------------

fn write(path: &Path, content: &str) {
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(path, content).unwrap();
}

#[test]
fn ratchet_end_to_end_new_debt_blocks_shrink_is_locked_in_growth_rejected() {
    let dir = std::env::temp_dir().join(format!("pallas-lint-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let lib = dir.join("rust/src/lib.rs");
    write(&lib, "fn f(o: Option<u32>) -> u32 { o.unwrap() }\n");

    // measure the starting debt and commit it as the baseline
    let report = lint_tree(&dir).unwrap();
    let counts = baseline::counts_of(&report.findings);
    assert_eq!(counts.get("rust/src/lib.rs|S2"), Some(&1));
    let bpath = dir.join(baseline::BASELINE_PATH);
    baseline::write_ratcheted(&bpath, &counts).unwrap();
    let committed = baseline::read(&bpath).unwrap();
    assert!(baseline::diff(&counts, &committed).is_clean());

    // a NEW violation (second unjustified unwrap) is a regression
    write(
        &lib,
        "fn f(o: Option<u32>) -> u32 { o.unwrap() }\n\
         fn g(o: Option<u32>) -> u32 { o.unwrap() }\n",
    );
    let grown = baseline::counts_of(&lint_tree(&dir).unwrap().findings);
    let d = baseline::diff(&grown, &committed);
    assert!(!d.is_clean(), "new debt must register as a regression");
    assert_eq!(d.regressions, vec![("rust/src/lib.rs|S2".to_string(), 2, 1)]);
    // ... and --write-baseline refuses to absorb it
    assert!(baseline::write_ratcheted(&bpath, &grown).is_err());
    assert_eq!(baseline::read(&bpath).unwrap(), committed, "rejected write must not alter file");

    // fixing the debt is clean against the old baseline and ratchets down
    write(
        &lib,
        "fn f(o: Option<u32>) -> u32 {\n    // PANIC: fixture — o is Some by construction\n    o.unwrap()\n}\n",
    );
    let fixed = baseline::counts_of(&lint_tree(&dir).unwrap().findings);
    assert!(fixed.is_empty());
    let d = baseline::diff(&fixed, &committed);
    assert!(d.is_clean(), "shrinking debt never blocks");
    assert_eq!(d.improvements, vec![("rust/src/lib.rs|S2".to_string(), 0, 1)]);
    baseline::write_ratcheted(&bpath, &fixed).unwrap();
    assert!(baseline::read(&bpath).unwrap().is_empty(), "ratchet-down must stick");

    let _ = std::fs::remove_dir_all(&dir);
}
