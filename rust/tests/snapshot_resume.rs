//! Checkpoint/resume equivalence: a session snapshotted at any round and
//! resumed must produce bit-identical results to an uninterrupted run —
//! across every method arm, thread count, and checkpoint cadence — and
//! damaged or mismatched snapshots must be rejected with typed errors,
//! never a panic or a silently-wrong resume.
//!
//! (The companion trace test in `rust/tests/trace.rs` pins that the
//! chrome-trace export of a resumed run is byte-identical too.)

mod common;

use common::{assert_tasks_bitwise_equal, measurer, native_backend, quick_cfg_trials};
use release::runtime::Backend;
use release::sim::{FaultConfig, FaultProfile};
use release::snapshot::SnapshotError;
use release::transfer::{TransferConfig, TransferMode};
use release::tuner::e2e::ModelTuneResult;
use release::tuner::session::{
    tune_model_session, tune_model_session_checkpointed, CheckpointSpec, SessionConfig,
    SessionError,
};
use release::tuner::MethodSpec;
use std::path::PathBuf;
use std::sync::Arc;

const MODEL: &str = "alexnet";
const MEAS_SEED: u64 = 7;

fn snap_path(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("release-snap-{}-{tag}.snap", std::process::id()))
}

fn serial_scfg(trials: usize, threads: usize) -> SessionConfig {
    SessionConfig {
        tuner: quick_cfg_trials(13, trials),
        threads,
        ..Default::default()
    }
}

fn run_plain(
    method: MethodSpec,
    scfg: &SessionConfig,
    backend: Option<Arc<dyn Backend>>,
) -> ModelTuneResult {
    tune_model_session(MODEL, &measurer(MEAS_SEED), method, scfg, backend)
        .expect("uninterrupted session")
}

/// The core property: (1) running with checkpointing on does not perturb
/// results, and (2) resuming from the run's last mid-flight snapshot
/// reproduces the reference bit-for-bit.
fn assert_checkpoint_resume_equivalent(
    tag: &str,
    method: MethodSpec,
    scfg: &SessionConfig,
    backend: Option<Arc<dyn Backend>>,
    every: usize,
    reference: &ModelTuneResult,
) {
    let path = snap_path(tag);
    let _ = std::fs::remove_file(&path);
    let spec = CheckpointSpec::new(path.clone(), every);
    let with_ckpt = tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        scfg,
        backend.clone(),
        Some(&spec),
        None,
    )
    .expect("checkpointed session");
    assert_tasks_bitwise_equal(reference, &with_ckpt);
    assert!(path.exists(), "{tag}: cadence {every} wrote no checkpoint");
    let resumed = tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        scfg,
        backend,
        Some(&spec),
        Some(&path),
    )
    .expect("resumed session");
    assert_tasks_bitwise_equal(reference, &resumed);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn all_six_arms_resume_bit_identically() {
    let arms: [(&str, bool); 6] = [
        ("autotvm", false),
        ("rl", true),
        ("sa+as", false),
        ("release", true),
        ("ga", false),
        ("random", false),
    ];
    for (k, (name, needs_backend)) in arms.iter().enumerate() {
        let method = MethodSpec::parse(name).expect(name);
        let backend = needs_backend.then(native_backend);
        // vary the task parallelism per arm so every method exercises both
        // the serial cadence and the quiesce-barrier checkpoint path
        let mut scfg = serial_scfg(48, 2);
        scfg.task_parallelism = [1, 2, 4][k % 3];
        scfg.device_slots = scfg.task_parallelism;
        let reference = run_plain(method, &scfg, backend.clone());
        // vary the cadence per arm so the resume point lands on different
        // rounds (including mid-task ones)
        let every = k % 3 + 1;
        assert_checkpoint_resume_equivalent(
            &format!("arm-{name}").replace('+', "_"),
            method,
            &scfg,
            backend,
            every,
            &reference,
        );
    }
}

#[test]
fn task_parallel_sessions_resume_bit_identically() {
    // checkpointing is no longer serial-only: at task_parallelism > 1 the
    // concurrent lanes quiesce at their next round boundary while one
    // worker serializes the whole session, and a resume must reproduce the
    // uninterrupted run bit-for-bit at tp 1, 2, and 4 alike
    let method = MethodSpec::sa_as();
    for tp in [1usize, 2, 4] {
        let mut scfg = serial_scfg(48, 2);
        scfg.task_parallelism = tp;
        scfg.device_slots = 2;
        scfg.pipeline_depth = 2;
        let reference = run_plain(method, &scfg, None);
        assert_checkpoint_resume_equivalent(
            &format!("tp-{tp}"),
            method,
            &scfg,
            None,
            2,
            &reference,
        );
    }
}

#[test]
fn every_cadence_resumes_bit_identically() {
    // 96 trials -> multiple rounds per task, so the cadences below place
    // the snapshot at round 1, 2, 3, 5, 9... positions: task starts,
    // mid-pipeline, and final-absorb boundaries are all hit
    let method = MethodSpec::autotvm();
    let scfg = serial_scfg(96, 1);
    let reference = run_plain(method, &scfg, None);
    for every in [1usize, 2, 3, 5, 9] {
        assert_checkpoint_resume_equivalent(
            &format!("cadence-{every}"),
            method,
            &scfg,
            None,
            every,
            &reference,
        );
    }
}

#[test]
fn resume_is_thread_count_invariant() {
    // the fingerprint deliberately excludes --threads: a snapshot taken at
    // --threads 1 must resume at 2 or 4 with bit-identical results
    let method = MethodSpec::sa_as();
    let reference = run_plain(method, &serial_scfg(96, 1), None);
    let path = snap_path("threads");
    let _ = std::fs::remove_file(&path);
    let spec = CheckpointSpec::new(path.clone(), 3);
    let ckpt_run = tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        &serial_scfg(96, 1),
        None,
        Some(&spec),
        None,
    )
    .expect("checkpointed at threads 1");
    assert_tasks_bitwise_equal(&reference, &ckpt_run);
    for threads in [1usize, 2, 4] {
        let resumed = tune_model_session_checkpointed(
            MODEL,
            &measurer(MEAS_SEED),
            method,
            &serial_scfg(96, threads),
            None,
            None,
            Some(&path),
        )
        .unwrap_or_else(|e| panic!("resume at --threads {threads}: {e}"));
        assert_tasks_bitwise_equal(&reference, &resumed);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn transfer_both_sessions_resume_bit_identically() {
    // --transfer both exercises the registry section of the snapshot (the
    // artifact store + audit log) and the PPO policy warm-start path
    let method = MethodSpec::release();
    let mut scfg = serial_scfg(48, 2);
    scfg.transfer = TransferConfig::with_mode(TransferMode::Both);
    let reference = run_plain(method, &scfg, Some(native_backend()));
    assert_checkpoint_resume_equivalent(
        "transfer-both",
        method,
        &scfg,
        Some(native_backend()),
        2,
        &reference,
    );
}

fn faulted_scfg(trials: usize, threads: usize) -> SessionConfig {
    let mut scfg = serial_scfg(trials, threads);
    scfg.device_slots = 2;
    scfg.faults = FaultConfig {
        profile: FaultProfile::Standard,
        fault_seed: 7,
        ..Default::default()
    };
    scfg
}

#[test]
fn faulted_sessions_resume_bit_identically() {
    // Snapshot a session mid-bad-day and resume: retry/backoff accounting,
    // quarantined configs (their failure causes included), and the
    // per-iteration slot-failure columns that drive slot ejection must all
    // come back exactly — the resumed run's degradation story is the
    // uninterrupted run's, bit for bit.
    let method = MethodSpec::sa_as();
    let scfg = faulted_scfg(48, 2);
    let reference = run_plain(method, &scfg, None);
    // the fault plan actually fired, so the equivalence below is not
    // vacuously comparing two clean runs
    assert!(
        reference.n_quarantined > 0
            || reference
                .tasks
                .iter()
                .any(|t| t.iterations.iter().any(|it| !it.slot_failures.is_empty())),
        "standard profile at fault seed 7 left no failure evidence"
    );
    assert_checkpoint_resume_equivalent("faulted", method, &scfg, None, 2, &reference);
}

#[test]
fn changed_fault_plan_is_refused_by_the_fingerprint() {
    // A snapshot records the fault plan it was taken under; resuming into
    // a different plan (another seed, or faults disabled) would splice two
    // incompatible measurement histories — the fingerprint must refuse.
    let method = MethodSpec::autotvm();
    let scfg = faulted_scfg(32, 1);
    let path = snap_path("fault-plan");
    let _ = std::fs::remove_file(&path);
    let spec = CheckpointSpec::new(path.clone(), 1);
    tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        &scfg,
        None,
        Some(&spec),
        None,
    )
    .expect("checkpointed faulted session");

    let resume_into = |scfg: &SessionConfig| {
        tune_model_session_checkpointed(
            MODEL,
            &measurer(MEAS_SEED),
            method,
            scfg,
            None,
            None,
            Some(&path),
        )
        .map(|_| ())
    };

    let mut reseeded = scfg.clone();
    reseeded.faults.fault_seed = 8;
    let err = resume_into(&reseeded).unwrap_err();
    assert!(
        matches!(
            err,
            SessionError::Snapshot(SnapshotError::FingerprintMismatch { .. })
        ),
        "fault seed change: {err:?}"
    );

    let mut disabled = scfg.clone();
    disabled.faults = FaultConfig::default();
    let err = resume_into(&disabled).unwrap_err();
    assert!(
        matches!(
            err,
            SessionError::Snapshot(SnapshotError::FingerprintMismatch { .. })
        ),
        "faults off: {err:?}"
    );

    // the matching plan still resumes
    resume_into(&scfg).expect("matching fault plan resumes");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn damaged_and_mismatched_snapshots_are_rejected() {
    // produce a real snapshot to tamper with
    let method = MethodSpec::autotvm();
    let scfg = serial_scfg(32, 1);
    let path = snap_path("tamper");
    let _ = std::fs::remove_file(&path);
    let spec = CheckpointSpec::new(path.clone(), 1);
    tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        &scfg,
        None,
        Some(&spec),
        None,
    )
    .expect("checkpointed session");
    let good = std::fs::read(&path).expect("snapshot bytes");
    assert!(good.len() > 28, "snapshot is just a header?");

    let resume_with = |bytes: &[u8], scfg: &SessionConfig| {
        std::fs::write(&path, bytes).expect("write tampered snapshot");
        tune_model_session_checkpointed(
            MODEL,
            &measurer(MEAS_SEED),
            method,
            scfg,
            None,
            None,
            Some(&path),
        )
        .map(|_| ())
    };

    // truncated payload: checksum can no longer match
    let err = resume_with(&good[..good.len() / 2], &scfg).unwrap_err();
    assert!(
        matches!(err, SessionError::Snapshot(SnapshotError::ChecksumMismatch)),
        "truncated: {err:?}"
    );
    // sub-header truncation
    let err = resume_with(&good[..10], &scfg).unwrap_err();
    assert!(
        matches!(err, SessionError::Snapshot(SnapshotError::UnexpectedEof)),
        "tiny: {err:?}"
    );
    // flipped payload byte
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0xff;
    let err = resume_with(&flipped, &scfg).unwrap_err();
    assert!(
        matches!(err, SessionError::Snapshot(SnapshotError::ChecksumMismatch)),
        "flipped: {err:?}"
    );
    // future format version (checked before the checksum, so a clear
    // version error wins over a generic corruption one)
    let mut vbump = good.clone();
    vbump[8] = vbump[8].wrapping_add(1);
    let err = resume_with(&vbump, &scfg).unwrap_err();
    assert!(
        matches!(
            err,
            SessionError::Snapshot(SnapshotError::VersionMismatch { .. })
        ),
        "version: {err:?}"
    );
    // a v2 (pre-lane layout) snapshot is likewise refused by the version
    // check — v3 readers never try to parse the retired RESULTS/TASK
    // sections
    let mut v2 = good.clone();
    v2[8] = 2;
    let err = resume_with(&v2, &scfg).unwrap_err();
    assert!(
        matches!(
            err,
            SessionError::Snapshot(SnapshotError::VersionMismatch { .. })
        ),
        "v2: {err:?}"
    );
    // wrong magic
    let mut bad_magic = good.clone();
    bad_magic[0] ^= 0xff;
    let err = resume_with(&bad_magic, &scfg).unwrap_err();
    assert!(
        matches!(err, SessionError::Snapshot(SnapshotError::BadMagic)),
        "magic: {err:?}"
    );
    // a different session configuration (seed changed) must be refused by
    // the fingerprint, not resumed into silently-wrong results
    let mut other = scfg.clone();
    other.tuner.seed ^= 1;
    let err = resume_with(&good, &other).unwrap_err();
    assert!(
        matches!(
            err,
            SessionError::Snapshot(SnapshotError::FingerprintMismatch { .. })
        ),
        "fingerprint: {err:?}"
    );
    // the pristine bytes still resume fine after all that
    resume_with(&good, &scfg).expect("pristine snapshot resumes");
    let _ = std::fs::remove_file(&path);
}

