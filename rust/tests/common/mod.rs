//! Shared fixtures for the integration-test binaries: one place for the
//! seeded design spaces, cheap tuner configs, deterministic measurers and
//! backend constructors that every test file used to copy-paste.
//!
//! Include with `mod common;` from a test file under `rust/tests/`.

#![allow(dead_code)] // each test binary uses its own subset of the harness

use release::nn::NativeBackend;
use release::runtime::Backend;
use release::sim::SimMeasurer;
use release::space::DesignSpace;
use release::tuner::e2e::ModelTuneResult;
use release::tuner::TunerConfig;
use release::workload::{ConvLayer, ConvTask};
use std::sync::Arc;

/// A deliberately small conv layer whose design space is a few thousand
/// points — large enough to search, small enough that a whole tune loop
/// runs in milliseconds.
pub fn tiny_layer() -> ConvLayer {
    ConvLayer::new(16, 8, 8, 16, 3, 3, 1, 1)
}

/// The seeded tiny design space.
pub fn tiny_space() -> DesignSpace {
    DesignSpace::for_conv(tiny_layer())
}

/// A small family of sibling conv tasks (power-of-two shape neighbours, so
/// their knob values remap into each other) — the transfer-test workload.
pub fn sibling_tasks() -> Vec<ConvTask> {
    let layers = [
        ConvLayer::new(32, 14, 14, 32, 3, 3, 1, 1),
        ConvLayer::new(64, 7, 7, 64, 3, 3, 1, 1),
        ConvLayer::new(32, 14, 14, 64, 3, 3, 1, 1),
    ];
    layers
        .iter()
        .enumerate()
        .map(|(i, &layer)| ConvTask {
            id: format!("tiny.c{}", i + 1),
            model: "tiny",
            index: i + 1,
            layer,
            occurrences: 1,
        })
        .collect()
}

/// Cheap tuner policy: small budget, default convergence, explicit seed.
pub fn quick_cfg(seed: u64) -> TunerConfig {
    TunerConfig { max_trials: 160, seed, ..Default::default() }
}

/// [`quick_cfg`] with an explicit measurement budget.
pub fn quick_cfg_trials(seed: u64, max_trials: usize) -> TunerConfig {
    TunerConfig { max_trials, seed, ..Default::default() }
}

/// The deterministic simulated Titan Xp (same seed = same "day" on the
/// machine: identical runtimes for identical configs).
pub fn measurer(seed: u64) -> SimMeasurer {
    SimMeasurer::titan_xp(seed)
}

/// The always-available pure-Rust PPO backend.
pub fn native_backend() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::new())
}

/// Assert two model-tune results describe bitwise-identical per-task
/// outcomes (schedules may differ in wall time; results must not).
pub fn assert_tasks_bitwise_equal(a: &ModelTuneResult, b: &ModelTuneResult) {
    assert_eq!(a.tasks.len(), b.tasks.len());
    assert_eq!(a.n_measurements, b.n_measurements);
    assert_eq!(a.inference_ms.to_bits(), b.inference_ms.to_bits());
    for (x, y) in a.tasks.iter().zip(&b.tasks) {
        assert_eq!(x.best_runtime_ms.to_bits(), y.best_runtime_ms.to_bits());
        assert_eq!(x.best_gflops.to_bits(), y.best_gflops.to_bits());
        assert_eq!(x.best_config, y.best_config);
        assert_eq!(x.n_measurements, y.n_measurements);
        assert_eq!(x.iterations.len(), y.iterations.len());
        assert_eq!(x.clock.measure_s.to_bits(), y.clock.measure_s.to_bits());
        assert_eq!(x.clock.search_s.to_bits(), y.clock.search_s.to_bits());
        assert_eq!(x.clock.model_s.to_bits(), y.clock.model_s.to_bits());
    }
}
