//! Cross-task transfer integration tests: the `--transfer off` overlay is
//! bit-identical to the baseline engine, warm-started siblings actually
//! consume donors, the RL policy warm-start engages end-to-end, and the
//! registry/budget disciplines hold under every (method, seed, mode,
//! parallelism) combination the property test throws at the session.

mod common;

use common::{assert_tasks_bitwise_equal, measurer, native_backend, quick_cfg_trials, sibling_tasks};
use release::transfer::{TransferConfig, TransferEvent, TransferMode, TransferRegistry};
use release::tuner::session::{
    tune_tasks_session, tune_tasks_session_observed, SessionConfig,
};
use release::tuner::{e2e::tune_tasks, MethodSpec, TunerConfig};
use release::util::prop::forall;
use release::workload::zoo;
use std::collections::HashSet;

#[test]
fn transfer_off_is_bit_identical_to_baseline_engine() {
    // The transfer subsystem must be a pure overlay: with --transfer off
    // (the default) the session engine produces bit-identical TuneResults
    // to the pre-transfer engine — pinned against the serial path and the
    // task-parallel depth-1 schedule.
    let tasks = zoo::alexnet();
    let cfg = quick_cfg_trials(31, 64);
    let serial = tune_tasks(
        "alexnet",
        &tasks,
        &measurer(9),
        MethodSpec::sa_as(),
        &cfg,
        None,
    );
    let off_serial = tune_tasks_session(
        "alexnet",
        &tasks,
        &measurer(9),
        MethodSpec::sa_as(),
        &SessionConfig::serial(cfg.clone()),
        None,
    );
    assert_tasks_bitwise_equal(&serial, &off_serial);
    assert!(off_serial.tasks.iter().all(|t| t.transfer.is_none()));

    let scfg = SessionConfig {
        tuner: cfg,
        task_parallelism: 4,
        device_slots: 4,
        pipeline_depth: 1,
        ..Default::default()
    };
    let off_parallel = tune_tasks_session(
        "alexnet",
        &tasks,
        &measurer(9),
        MethodSpec::sa_as(),
        &scfg,
        None,
    );
    assert_tasks_bitwise_equal(&serial, &off_parallel);
}

#[test]
fn model_transfer_feeds_donor_pairs_to_later_tasks() {
    let tasks = sibling_tasks();
    let cfg = quick_cfg_trials(5, 64);

    let cold = tune_tasks_session(
        "tiny",
        &tasks,
        &measurer(21),
        MethodSpec::sa_as(),
        &SessionConfig::serial(cfg.clone()),
        None,
    );
    let mut scfg = SessionConfig::serial(cfg);
    scfg.transfer = TransferConfig::with_mode(TransferMode::Model);
    let registry = TransferRegistry::new();
    let warm = tune_tasks_session_observed(
        "tiny",
        &tasks,
        &measurer(21),
        MethodSpec::sa_as(),
        &scfg,
        None,
        Some(&registry),
    );

    // every task published; all but the curriculum-first consumed donors
    assert_eq!(registry.len(), tasks.len());
    assert_eq!(warm.n_warm_started(), tasks.len() - 1);
    for t in &warm.tasks {
        if let Some(s) = &t.transfer {
            assert!(!s.donors.is_empty());
            assert!(s.n_pairs > 0, "{}: donors but no remapped pairs", t.task_id);
            assert!(!s.policy_warm, "model mode must not touch the policy");
        }
        assert!(t.best_gflops > 0.0, "{} found nothing", t.task_id);
        assert!(t.n_measurements <= 64);
    }
    // the curriculum-first task ran cold: bitwise equal to the cold run
    let first = warm
        .tasks
        .iter()
        .position(|t| t.transfer.is_none())
        .expect("one task must run cold");
    assert_eq!(
        warm.tasks[first].best_runtime_ms.to_bits(),
        cold.tasks[first].best_runtime_ms.to_bits()
    );
    assert_eq!(warm.tasks[first].n_measurements, cold.tasks[first].n_measurements);
    // ...and the warm-started ones genuinely searched differently
    let changed = warm.tasks.iter().zip(&cold.tasks).any(|(w, c)| {
        w.transfer.is_some()
            && (w.n_measurements != c.n_measurements
                || w.best_runtime_ms.to_bits() != c.best_runtime_ms.to_bits()
                || w.iterations.len() != c.iterations.len())
    });
    assert!(changed, "transfer enabled but every task tuned identically to cold");
}

#[test]
fn transfer_session_is_deterministic_at_unit_parallelism() {
    // with tp = 1 the curriculum and donor sets are fixed, so a transfer
    // session is exactly reproducible run to run
    let tasks = sibling_tasks();
    let run = || {
        let mut scfg = SessionConfig::serial(quick_cfg_trials(3, 48));
        scfg.transfer = TransferConfig::with_mode(TransferMode::Model);
        tune_tasks_session(
            "tiny",
            &tasks,
            &measurer(33),
            MethodSpec::sa_as(),
            &scfg,
            None,
        )
    };
    let a = run();
    let b = run();
    assert_tasks_bitwise_equal(&a, &b);
}

#[test]
fn policy_transfer_warm_starts_the_rl_agent() {
    // RELEASE (RL) method, policy-only transfer: later tasks must adopt
    // the averaged donor parameters (policy_warm) and still tune fine.
    let tasks = sibling_tasks();
    let mut scfg = SessionConfig::serial(quick_cfg_trials(7, 32));
    scfg.transfer = TransferConfig::with_mode(TransferMode::Policy);
    let registry = TransferRegistry::new();
    let r = tune_tasks_session_observed(
        "tiny",
        &tasks,
        &measurer(41),
        MethodSpec::release(),
        &scfg,
        Some(native_backend()),
        Some(&registry),
    );
    assert_eq!(registry.len(), tasks.len());
    assert_eq!(r.n_warm_started(), tasks.len() - 1);
    for t in &r.tasks {
        assert!(t.best_gflops > 0.0, "{} found nothing", t.task_id);
        if let Some(s) = &t.transfer {
            assert!(s.policy_warm, "{}: donors but no policy warm-start", t.task_id);
            assert_eq!(s.n_pairs, 0, "policy mode must not seed the cost model");
        }
    }
}

#[test]
fn transfer_budget_and_registry_discipline_property() {
    // Property: across methods, seeds, transfer modes, parallelism and
    // pipeline depth, (a) no task ever exceeds its measurement budget and
    // (b) every donor a task reads was published by a *completed* task
    // before the read — no read-your-own-writes under task-parallelism.
    let tasks = sibling_tasks();
    let methods = [MethodSpec::autotvm(), MethodSpec::sa_as()];
    let modes = [
        TransferMode::Off,
        TransferMode::Model,
        TransferMode::Policy,
        TransferMode::Both,
    ];
    forall(6, 0x7a5f, |rng| {
        let mode = modes[rng.below(modes.len())];
        // one case in four exercises the RL arm (policy transfer end to end)
        let use_rl = rng.bool(0.25);
        let method = if use_rl {
            MethodSpec::release()
        } else {
            methods[rng.below(methods.len())]
        };
        let backend = if use_rl { Some(native_backend()) } else { None };
        let max_trials = 24 + rng.below(41);
        let seed = rng.next_u64();
        let scfg = SessionConfig {
            tuner: TunerConfig { max_trials, seed, ..Default::default() },
            task_parallelism: 1 + rng.below(3),
            device_slots: 1 + rng.below(2),
            pipeline_depth: 1 + rng.below(2),
            budget_shares: None,
            transfer: TransferConfig::with_mode(mode),
            ..Default::default()
        };
        let registry = TransferRegistry::new();
        let r = tune_tasks_session_observed(
            "tiny",
            &tasks,
            &measurer(seed ^ 0x5eed),
            method,
            &scfg,
            backend,
            Some(&registry),
        );
        // (a) budget discipline, transfer or not
        for t in &r.tasks {
            assert!(
                t.n_measurements <= max_trials,
                "{} overspent: {} > {max_trials} (seed {seed}, mode {})",
                t.task_id,
                t.n_measurements,
                mode.name()
            );
        }
        // (b) registry discipline: replay the event log
        let events = registry.events();
        if mode.is_off() {
            assert!(events.is_empty(), "off mode must never touch the registry");
        } else {
            let mut published: HashSet<String> = HashSet::new();
            let mut n_published = 0;
            for e in events {
                match e {
                    TransferEvent::Published { task } => {
                        assert!(published.insert(task), "double publish");
                        n_published += 1;
                    }
                    TransferEvent::Consulted { task, donors } => {
                        assert!(
                            !donors.contains(&task),
                            "{task} read its own artifact"
                        );
                        for d in &donors {
                            assert!(
                                published.contains(d),
                                "{task} read donor {d} before it completed \
                                 (seed {seed}, tp {})",
                                scfg.task_parallelism
                            );
                        }
                    }
                }
            }
            assert_eq!(n_published, tasks.len(), "every task must publish once");
        }
    });
}
