//! Single-lane eviction: one in-flight lane extracted from a session
//! snapshot (`evict_lane`) must load back (`load_lane`) and drive to
//! completion standalone with results bit-identical to the task's outcome
//! in an uninterrupted session — the daemon's planned migration primitive.

mod common;

use common::{measurer, quick_cfg_trials};
use release::coordinator::MeasureCoordinator;
use release::snapshot::SnapshotError;
use release::tuner::session::{
    evict_lane, lane_config, load_lane, tune_model_session,
    tune_model_session_checkpointed, CheckpointSpec, SessionConfig,
};
use release::tuner::MethodSpec;
use release::workload::zoo;
use std::path::PathBuf;

const MODEL: &str = "alexnet";
const MEAS_SEED: u64 = 7;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("release-lane-{}-{tag}", std::process::id()))
}

#[test]
fn evicted_lane_completes_standalone_bit_identically() {
    let method = MethodSpec::sa_as();
    let scfg = SessionConfig {
        tuner: quick_cfg_trials(13, 64),
        threads: 1,
        ..Default::default()
    };
    let reference = tune_model_session(MODEL, &measurer(MEAS_SEED), method, &scfg, None)
        .expect("uninterrupted session");

    // cadence 1: checkpoints are written inside a lane's step loop, so the
    // final snapshot on disk holds the last task mid-flight and every
    // earlier task completed
    let snap = tmp("session.snap");
    let _ = std::fs::remove_file(&snap);
    let spec = CheckpointSpec::new(snap.clone(), 1);
    let full = tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        &scfg,
        None,
        Some(&spec),
        None,
    )
    .expect("checkpointed session");
    common::assert_tasks_bitwise_equal(&reference, &full);
    assert!(snap.exists(), "cadence 1 wrote no checkpoint");

    let tasks = zoo::model_tasks(MODEL).expect("alexnet is in the zoo");
    let n = tasks.len();
    let last = n - 1;
    let lane_file = tmp("lane.snap");
    let _ = std::fs::remove_file(&lane_file);
    evict_lane(&snap, last, &lane_file).expect("evict the in-flight lane");
    assert!(lane_file.exists());

    // a completed lane refuses eviction (its result lives in the session
    // snapshot), and an out-of-range index is a typed error — in both
    // cases no lane file is produced
    let reject = tmp("reject.snap");
    let _ = std::fs::remove_file(&reject);
    let err = evict_lane(&snap, 0, &reject).unwrap_err();
    assert!(matches!(err, SnapshotError::Unsupported(_)), "done lane: {err:?}");
    let err = evict_lane(&snap, n + 5, &reject).unwrap_err();
    assert!(matches!(err, SnapshotError::Unsupported(_)), "out of range: {err:?}");
    assert!(!reject.exists(), "rejected evictions must not write a file");

    // resurrect the lane outside the session and drive it to completion
    // with the same measurement stream the session would have used
    let cfg = lane_config(&scfg, n, last);
    let meas = measurer(MEAS_SEED);
    let mut lane = load_lane(&lane_file, &tasks[last], method, &cfg, None, 1)
        .expect("load the evicted lane");
    assert_eq!(lane.index(), last);
    assert!(lane.rounds() > 0, "an in-flight lane has absorbed rounds");
    let coordinator =
        MeasureCoordinator::new(&meas, scfg.tuner.measure_workers.max(1));
    while !lane.step(&coordinator) {}
    let got = lane.finish(None);

    // bit-identical to the task's outcome in the uninterrupted session
    // (wall times excluded: they belong to the session schedule replay)
    let want = &reference.tasks[last];
    assert_eq!(got.task_id, want.task_id);
    assert_eq!(got.best_runtime_ms.to_bits(), want.best_runtime_ms.to_bits());
    assert_eq!(got.best_gflops.to_bits(), want.best_gflops.to_bits());
    assert_eq!(got.best_config, want.best_config);
    assert_eq!(got.n_measurements, want.n_measurements);
    assert_eq!(got.iterations.len(), want.iterations.len());
    assert_eq!(got.clock.measure_s.to_bits(), want.clock.measure_s.to_bits());
    assert_eq!(got.clock.search_s.to_bits(), want.clock.search_s.to_bits());
    assert_eq!(got.clock.model_s.to_bits(), want.clock.model_s.to_bits());

    // the session snapshot is untouched by the eviction and still resumes
    let resumed = tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        &scfg,
        None,
        None,
        Some(&snap),
    )
    .expect("session snapshot still resumes after eviction");
    common::assert_tasks_bitwise_equal(&reference, &resumed);

    // a lane file is not a session snapshot: resuming a session from it is
    // rejected (fingerprint matches, but the layout check refuses it)
    let err = tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        &scfg,
        None,
        None,
        Some(&lane_file),
    )
    .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("checkpoint error"), "{msg}");

    let _ = std::fs::remove_file(&snap);
    let _ = std::fs::remove_file(&lane_file);
}

#[test]
fn lane_file_rejects_mismatched_reload() {
    // build a session snapshot with an in-flight lane, evict it, then try
    // to load it back under the wrong task / depth — Lane::resume must
    // refuse with a typed corruption error, never resurrect a wrong lane
    let method = MethodSpec::autotvm();
    let scfg = SessionConfig {
        tuner: quick_cfg_trials(5, 48),
        threads: 1,
        ..Default::default()
    };
    let snap = tmp("mismatch-session.snap");
    let _ = std::fs::remove_file(&snap);
    let spec = CheckpointSpec::new(snap.clone(), 1);
    tune_model_session_checkpointed(
        MODEL,
        &measurer(MEAS_SEED),
        method,
        &scfg,
        None,
        Some(&spec),
        None,
    )
    .expect("checkpointed session");

    let tasks = zoo::model_tasks(MODEL).expect("alexnet is in the zoo");
    let n = tasks.len();
    let last = n - 1;
    let lane_file = tmp("mismatch-lane.snap");
    let _ = std::fs::remove_file(&lane_file);
    evict_lane(&snap, last, &lane_file).expect("evict");

    let cfg = lane_config(&scfg, n, last);
    // wrong pipeline depth
    let err = load_lane(&lane_file, &tasks[last], method, &cfg, None, 2).unwrap_err();
    assert!(matches!(err, SnapshotError::Corrupt(_)), "depth: {err:?}");
    // wrong task for the payload
    let err = load_lane(&lane_file, &tasks[0], method, &cfg, None, 1).unwrap_err();
    assert!(matches!(err, SnapshotError::Corrupt(_)), "task: {err:?}");
    // the matching reload still works
    load_lane(&lane_file, &tasks[last], method, &cfg, None, 1).expect("matching reload");

    let _ = std::fs::remove_file(&snap);
    let _ = std::fs::remove_file(&lane_file);
}
