//! Determinism-under-parallelism pins for the flat-buffer hot-path
//! refactor: tuner results (best config + GFLOPS, per method arm) must be
//! bit-identical across `--threads 1/2/4`.
//!
//! Equivalence with the pre-refactor serial behavior is pinned at the
//! component level (the layer where "same arithmetic, same order" can be
//! stated exactly): feature rows byte-equal `features()`
//! (`costmodel::tests`), incremental binning equals from-scratch binning
//! (`gbt::tree::tests` + `costmodel::tests`), index-slice tree fits equal
//! gathered-copy fits (`gbt::tree::tests`), the blocked matmul equals the
//! naive triple loop bitwise (`nn::ops::tests`), and `mutate_into`
//! consumes the RNG exactly as `mutate` (`space::tests`). Every parallel
//! sweep writes per-item-independent outputs in place, so the thread count
//! can change only wall-clock, never values — which is what this file
//! asserts end to end.

mod common;

use common::{measurer, native_backend, tiny_layer};
use release::tuner::{tune, MethodSpec, TuneResult, TunerConfig};
use release::util::parallel::{
    par_indexed_mut, par_map, par_rows_mut, set_dispatch, set_threads, thread_knob_guard,
    Dispatch,
};
use release::util::prop::forall;
use release::workload::ConvTask;

fn tiny_task() -> ConvTask {
    ConvTask {
        id: "tiny.hot".to_string(),
        model: "tiny",
        index: 0,
        layer: tiny_layer(),
        occurrences: 1,
    }
}

fn assert_bitwise_equal_runs(name: &str, a: &TuneResult, b: &TuneResult) {
    assert_eq!(a.best_config, b.best_config, "{name}: best config diverged");
    assert_eq!(
        a.best_gflops.to_bits(),
        b.best_gflops.to_bits(),
        "{name}: best GFLOPS diverged"
    );
    assert_eq!(
        a.best_runtime_ms.to_bits(),
        b.best_runtime_ms.to_bits(),
        "{name}: best runtime diverged"
    );
    assert_eq!(a.n_measurements, b.n_measurements, "{name}: budget spend diverged");
    assert_eq!(a.iterations.len(), b.iterations.len(), "{name}: iteration count");
    for (x, y) in a.iterations.iter().zip(&b.iterations) {
        assert_eq!(
            x.best_gflops.to_bits(),
            y.best_gflops.to_bits(),
            "{name}: per-iteration best diverged at iter {}",
            x.iter
        );
        assert_eq!(x.cum_measured, y.cum_measured, "{name}: iter {}", x.iter);
        assert_eq!(x.sampler_k, y.sampler_k, "{name}: sampler k at iter {}", x.iter);
    }
    assert_eq!(
        a.clock.search_s.to_bits(),
        b.clock.search_s.to_bits(),
        "{name}: search clock diverged"
    );
    assert_eq!(
        a.clock.model_s.to_bits(),
        b.clock.model_s.to_bits(),
        "{name}: model clock diverged"
    );
}

/// The acceptance pin: every method arm — SA/GA/random search, greedy and
/// adaptive sampling, and the RL (PPO) arms on the native backend — tunes
/// to bit-identical results at `--threads` 1, 2 and 4.
#[test]
fn tune_results_bit_identical_across_thread_counts_all_arms() {
    let _knob = thread_knob_guard();
    let task = tiny_task();
    let arms: [(&str, bool); 6] = [
        ("autotvm", false),
        ("ga", false),
        ("random", false),
        ("sa+as", false),
        ("rl", true),
        ("release", true),
    ];
    for (name, needs_backend) in arms {
        let method = MethodSpec::parse(name).unwrap();
        let cfg = TunerConfig {
            max_trials: if needs_backend { 40 } else { 96 },
            seed: 11,
            ..Default::default()
        };
        let mut runs = Vec::new();
        for threads in [1usize, 2, 4] {
            set_threads(threads);
            let backend = if needs_backend { Some(native_backend()) } else { None };
            runs.push(tune(&task, &measurer(5), method, &cfg, backend));
        }
        set_threads(0);
        assert!(
            runs[0].best_gflops > 0.0,
            "{name}: found nothing on the tiny task"
        );
        for r in &runs[1..] {
            assert_bitwise_equal_runs(name, &runs[0], r);
        }
    }
}

/// Property test for the three parallel primitives across edge shapes —
/// empty, singleton, fewer items than threads, non-dividing lengths, and
/// `dim` far wider than the row count — asserting bit-identity with the
/// serial path at threads ∈ {1, 2, 3, 8} on the persistent pool.
#[test]
fn parallel_primitives_bit_identical_across_edge_shapes() {
    let shapes: [usize; 8] = [0, 1, 2, 3, 7, 8, 13, 257];
    forall(25, 0x9001, |rng| {
        let n = shapes[rng.below(shapes.len())];
        let salt = rng.below(1 << 20) as u64;
        let items: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(salt + 3)).collect();

        // par_map: serial reference is threads = 1
        let want: Vec<u64> = par_map(&items, 1, |&x| x ^ salt);
        for t in [2usize, 3, 8] {
            assert_eq!(par_map(&items, t, |&x| x ^ salt), want, "par_map n={n} t={t}");
        }

        // par_indexed_mut
        let mut want_idx = vec![0f64; n];
        par_indexed_mut(&mut want_idx, 1, |i, s| *s = (i as f64 + 0.25) * salt as f64);
        for t in [2usize, 3, 8] {
            let mut out = vec![0f64; n];
            par_indexed_mut(&mut out, t, |i, s| *s = (i as f64 + 0.25) * salt as f64);
            assert_eq!(out, want_idx, "par_indexed_mut n={n} t={t}");
        }

        // par_rows_mut, including dim >> rows (wide rows, tiny row count)
        for (rows, dim) in [(n, 3), (2, 512), (n, 1)] {
            let fill = |i: usize, row: &mut [f32]| {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = (i * dim + j) as f32 + salt as f32;
                }
            };
            let mut want_rows = vec![0f32; rows * dim];
            par_rows_mut(&mut want_rows, dim, 1, fill);
            for t in [2usize, 3, 8] {
                let mut out = vec![0f32; rows * dim];
                par_rows_mut(&mut out, dim, t, fill);
                assert_eq!(out, want_rows, "par_rows_mut rows={rows} dim={dim} t={t}");
            }
        }
    });
}

/// Pool-reuse pin: consecutive sweeps with different closure types over
/// the same persistent workers must not leak any state between them, and
/// interleaving with a tuner run (which exercises the pool internally)
/// must leave later primitive sweeps untouched.
#[test]
fn pool_reuse_across_sweeps_and_tuner_runs_no_state_leakage() {
    let first: Vec<u64> = par_map(&(0..400u64).collect::<Vec<_>>(), 4, |&x| x * x);
    assert!(first.iter().enumerate().all(|(i, &v)| v == (i * i) as u64));

    // a full tuner run pushes many unrelated closures through the pool
    let task = tiny_task();
    let cfg = TunerConfig { max_trials: 32, seed: 2, ..Default::default() };
    let r = tune(&task, &measurer(3), MethodSpec::sa_as(), &cfg, None);
    assert!(r.best_gflops > 0.0);

    let mut second = vec![String::new(); 300];
    par_indexed_mut(&mut second, 4, |i, s| *s = format!("row-{i}"));
    assert!(second.iter().enumerate().all(|(i, s)| s == &format!("row-{i}")));
}

/// Pool determinism under the thread-knob guard: flipping the global
/// `--threads` knob (and the dispatch backend) between runs of the same
/// sweep must never change a single bit of output.
#[test]
fn pool_under_thread_knob_guard_is_deterministic() {
    let _knob = thread_knob_guard();
    let xs: Vec<f64> = (0..1023).map(|i| (i as f64 * 0.37).cos()).collect();
    let sweep = || {
        let mut out = vec![0f64; xs.len()];
        par_indexed_mut(
            &mut out,
            release::util::parallel::threads(),
            |i, s| *s = xs[i].mul_add(3.0, -1.0),
        );
        out
    };
    set_threads(1);
    let reference = sweep();
    for t in [2usize, 3, 4, 8] {
        set_threads(t);
        assert_eq!(sweep(), reference, "threads {t}");
    }
    set_dispatch(Dispatch::Scoped);
    set_threads(4);
    assert_eq!(sweep(), reference, "scoped dispatch");
    set_dispatch(Dispatch::Pool);
    set_threads(0);
}

/// End-to-end pin of the dispatch refactor: the persistent pool must tune
/// to exactly the results the PR 4 scoped spawn-per-call dispatch produced
/// (same partitioning, disjoint outputs — so same bits, less overhead).
#[test]
fn pool_dispatch_matches_scoped_dispatch_end_to_end() {
    let _knob = thread_knob_guard();
    let task = tiny_task();
    let cfg = TunerConfig { max_trials: 64, seed: 13, ..Default::default() };
    set_threads(4);
    set_dispatch(Dispatch::Pool);
    let pool = tune(&task, &measurer(7), MethodSpec::sa_as(), &cfg, None);
    set_dispatch(Dispatch::Scoped);
    let scoped = tune(&task, &measurer(7), MethodSpec::sa_as(), &cfg, None);
    set_dispatch(Dispatch::Pool);
    set_threads(0);
    assert!(pool.best_gflops > 0.0);
    assert_bitwise_equal_runs("pool-vs-scoped", &pool, &scoped);
}

/// A larger adaptive-sampling run on a real zoo layer: the trajectory is
/// big enough to cross the parallel thresholds (speculative knee sweep,
/// parallel Lloyd assignment, parallel batch predict), so this pins the
/// thread-invariance of exactly the paths the small task may not reach.
#[test]
fn adaptive_arm_thread_invariance_on_zoo_layer() {
    let _knob = thread_knob_guard();
    let task = release::workload::zoo::resnet18()[5].clone();
    let cfg = TunerConfig { max_trials: 128, seed: 7, ..Default::default() };
    set_threads(1);
    let serial = tune(&task, &measurer(9), MethodSpec::sa_as(), &cfg, None);
    set_threads(4);
    let par = tune(&task, &measurer(9), MethodSpec::sa_as(), &cfg, None);
    set_threads(0);
    assert!(serial.best_gflops > 0.0);
    assert_bitwise_equal_runs("sa+as/resnet18", &serial, &par);
}
