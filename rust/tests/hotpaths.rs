//! Determinism-under-parallelism pins for the flat-buffer hot-path
//! refactor: tuner results (best config + GFLOPS, per method arm) must be
//! bit-identical across `--threads 1/2/4`.
//!
//! Equivalence with the pre-refactor serial behavior is pinned at the
//! component level (the layer where "same arithmetic, same order" can be
//! stated exactly): feature rows byte-equal `features()`
//! (`costmodel::tests`), incremental binning equals from-scratch binning
//! (`gbt::tree::tests` + `costmodel::tests`), index-slice tree fits equal
//! gathered-copy fits (`gbt::tree::tests`), the blocked matmul equals the
//! naive triple loop bitwise (`nn::ops::tests`), and `mutate_into`
//! consumes the RNG exactly as `mutate` (`space::tests`). Every parallel
//! sweep writes per-item-independent outputs in place, so the thread count
//! can change only wall-clock, never values — which is what this file
//! asserts end to end.

mod common;

use common::{measurer, native_backend, tiny_layer};
use release::tuner::{tune, MethodSpec, TuneResult, TunerConfig};
use release::util::parallel::{set_threads, thread_knob_guard};
use release::workload::ConvTask;

fn tiny_task() -> ConvTask {
    ConvTask {
        id: "tiny.hot".to_string(),
        model: "tiny",
        index: 0,
        layer: tiny_layer(),
        occurrences: 1,
    }
}

fn assert_bitwise_equal_runs(name: &str, a: &TuneResult, b: &TuneResult) {
    assert_eq!(a.best_config, b.best_config, "{name}: best config diverged");
    assert_eq!(
        a.best_gflops.to_bits(),
        b.best_gflops.to_bits(),
        "{name}: best GFLOPS diverged"
    );
    assert_eq!(
        a.best_runtime_ms.to_bits(),
        b.best_runtime_ms.to_bits(),
        "{name}: best runtime diverged"
    );
    assert_eq!(a.n_measurements, b.n_measurements, "{name}: budget spend diverged");
    assert_eq!(a.iterations.len(), b.iterations.len(), "{name}: iteration count");
    for (x, y) in a.iterations.iter().zip(&b.iterations) {
        assert_eq!(
            x.best_gflops.to_bits(),
            y.best_gflops.to_bits(),
            "{name}: per-iteration best diverged at iter {}",
            x.iter
        );
        assert_eq!(x.cum_measured, y.cum_measured, "{name}: iter {}", x.iter);
        assert_eq!(x.sampler_k, y.sampler_k, "{name}: sampler k at iter {}", x.iter);
    }
    assert_eq!(
        a.clock.search_s.to_bits(),
        b.clock.search_s.to_bits(),
        "{name}: search clock diverged"
    );
    assert_eq!(
        a.clock.model_s.to_bits(),
        b.clock.model_s.to_bits(),
        "{name}: model clock diverged"
    );
}

/// The acceptance pin: every method arm — SA/GA/random search, greedy and
/// adaptive sampling, and the RL (PPO) arms on the native backend — tunes
/// to bit-identical results at `--threads` 1, 2 and 4.
#[test]
fn tune_results_bit_identical_across_thread_counts_all_arms() {
    let _knob = thread_knob_guard();
    let task = tiny_task();
    let arms: [(&str, bool); 6] = [
        ("autotvm", false),
        ("ga", false),
        ("random", false),
        ("sa+as", false),
        ("rl", true),
        ("release", true),
    ];
    for (name, needs_backend) in arms {
        let method = MethodSpec::parse(name).unwrap();
        let cfg = TunerConfig {
            max_trials: if needs_backend { 40 } else { 96 },
            seed: 11,
            ..Default::default()
        };
        let mut runs = Vec::new();
        for threads in [1usize, 2, 4] {
            set_threads(threads);
            let backend = if needs_backend { Some(native_backend()) } else { None };
            runs.push(tune(&task, &measurer(5), method, &cfg, backend));
        }
        set_threads(0);
        assert!(
            runs[0].best_gflops > 0.0,
            "{name}: found nothing on the tiny task"
        );
        for r in &runs[1..] {
            assert_bitwise_equal_runs(name, &runs[0], r);
        }
    }
}

/// A larger adaptive-sampling run on a real zoo layer: the trajectory is
/// big enough to cross the parallel thresholds (speculative knee sweep,
/// parallel Lloyd assignment, parallel batch predict), so this pins the
/// thread-invariance of exactly the paths the small task may not reach.
#[test]
fn adaptive_arm_thread_invariance_on_zoo_layer() {
    let _knob = thread_knob_guard();
    let task = release::workload::zoo::resnet18()[5].clone();
    let cfg = TunerConfig { max_trials: 128, seed: 7, ..Default::default() };
    set_threads(1);
    let serial = tune(&task, &measurer(9), MethodSpec::sa_as(), &cfg, None);
    set_threads(4);
    let par = tune(&task, &measurer(9), MethodSpec::sa_as(), &cfg, None);
    set_threads(0);
    assert!(serial.best_gflops > 0.0);
    assert_bitwise_equal_runs("sa+as/resnet18", &serial, &par);
}
