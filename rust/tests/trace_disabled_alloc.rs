//! Disabled-path cost contract: with tracing off, every obs entry point
//! (span emitters, metrics counters/histograms, timeline anchors) must be
//! allocation-free — the instrumented hot loops pay one atomic load and
//! nothing else. Enforced with a counting global allocator, so this test
//! lives in its own binary with exactly one `#[test]` (a concurrent test
//! would pollute the allocation window).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use release::obs;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation verbatim to `System` (a correct
// allocator); the only addition is a relaxed counter bump, which cannot
// violate the GlobalAlloc contract.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds the GlobalAlloc contract; forwarded unchanged.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: `ptr` came from this allocator (which forwards to `System`)
    // with the same layout, per the caller's contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn disabled_tracing_allocates_nothing() {
    use obs::metrics::{add, inc, observe, Counter, Histogram};
    assert!(!obs::enabled(), "tracing must start disabled");

    // the counting allocator itself works
    let sanity = ALLOCS.load(Ordering::Relaxed);
    let probe = vec![0u8; 64];
    assert!(ALLOCS.load(Ordering::Relaxed) > sanity, "allocator not counting");
    drop(probe);

    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..100_000u64 {
        inc(Counter::SearchRounds);
        add(Counter::ConfigsSampled, i);
        observe(Histogram::MeasureBatchConfigs, i);
        obs::emit_ctx("cat", "name", i, 1, &[("a", 1.0), ("b", 2.0)]);
        obs::emit_serial(obs::LANE_SESSION, "cat", "name", i, 1, &[]);
        obs::set_ctx_base(i);
        std::hint::black_box(obs::ctx_base());
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "disabled obs entry points must not allocate (saw {} allocations)",
        after - before
    );
    assert_eq!(obs::metrics::total_counted(), 0, "disabled metrics must not record");
}
